"""Serving launcher: batched LM prefill + decode, or the Knowledge-Bank
serving mode.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
      --batch 4 --prompt-len 32 --gen 16

  PYTHONPATH=src python -m repro.launch.serve --kb --kb-backend pallas \
      --clients 8 --kb-search ivf --nlist 64 --nprobe 8

  PYTHONPATH=src python -m repro.launch.serve --kb --listen 127.0.0.1:7787

LM mode runs a reduced config end-to-end: prefill the prompt batch, then
greedy decode. Full-size serve programs (decode_32k / long_500k) are
exercised via the dry-run lowering of the same ``decode_step``.

KB mode stands up the request-coalescing KnowledgeBankServer on the chosen
engine backend (dense | pallas | sharded — sharded gets a host mesh) and
drives it with concurrent lookup/lazy_grad/nn_search clients — the Figure-1
serving topology without the trainer attached. ``--kb-search ivf`` serves
nn_search from the asynchronously-clustered IVF index, rebuilt by a
background refresher thread (repro.core.ann_index); with ``--kb-backend
sharded`` each bank shard carries its own sub-index, queries merge
per-shard shortlists hierarchically, and stale shards re-cluster
independently. See docs/tuning.md for the knob guide.

``--listen HOST:PORT`` exposes the same bank on the TCP wire protocol
(repro.core.kb_transport) instead of driving synthetic local clients:
separate trainer/maker PROCESSES connect with ``launch/train.py
--kb-connect`` and ``launch/maker_worker.py --connect``, and their requests
coalesce with any in-process traffic. Port 0 binds an ephemeral port
(printed on the "listening" line). Serves until SIGINT/SIGTERM or
``--serve-seconds``, then prints the same serving summary.

Scale-out (repro.core.kb_router): ``--kb-partitions N`` splits the id
space over N in-process partition servers behind a ``KBRouter`` and drives
THAT with the synthetic clients — the one-process rehearsal of the
partitioned fleet. ``--kb-join I/N`` makes this process partition I of an
N-member fleet instead: it hosts ONLY the rows the consistent-hash ring
assigns to slot I (requires ``--listen``; ``--kb-entries`` is the GLOBAL
bank size, identical across the fleet), labels its handshake "I/N", and
refuses clients that pinned a different slot. Routers and workers connect
with a comma list in ring order: ``--kb-connect host:p0,host:p1``.
``--kb-reorder`` enables cross-op reordering in the dispatcher (commuting
requests hoist across the queue into bigger batched dispatches).
"""
from __future__ import annotations

import argparse
import signal
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.env import add_device_args, apply_device_args
from repro.models import build_model
from repro.sharding.partition import DistContext


def serve_kb_partitioned(args) -> None:
    """``--kb-partitions N``: the scale-out topology in one process — N
    partition servers behind a ``KBRouter``, synthetic clients driving the
    router. The cross-process version of the same fleet is N ``--kb-join``
    processes plus router-connected workers."""
    from repro.core import (InProcessTransport, KBRouter,
                            KnowledgeBankServer, PartitionMap)
    P = args.kb_partitions
    pmap = PartitionMap(args.kb_entries, P)
    servers = [KnowledgeBankServer(int(pmap.counts[p]), args.kb_dim,
                                   backend=args.kb_backend,
                                   coalesce=not args.no_coalesce,
                                   reorder=args.kb_reorder,
                                   search_mode=args.kb_search,
                                   ann_nlist=args.nlist,
                                   ann_nprobe=args.nprobe,
                                   storage=args.kb_storage,
                                   cache_rows=args.kb_cache_rows,
                                   resident_rows=args.kb_resident_rows,
                                   cold_after_rows=args.kb_cold_after,
                                   cold_dir=args.kb_cold_dir or None)
               for p in range(P)]
    router = KBRouter([InProcessTransport(s, partition=f"{p}/{P}")
                       for p, s in enumerate(servers)], pmap=pmap)
    rng = np.random.default_rng(args.seed)
    fill_vals = rng.normal(size=(args.kb_entries, args.kb_dim)) \
        .astype(np.float32)
    # tiered banks bound the distinct rows one write may touch — chunk the
    # initial fill to fit the resident tier
    chunk = (min(args.kb_resident_rows, args.kb_entries)
             if args.kb_resident_rows else args.kb_entries)
    for lo in range(0, args.kb_entries, chunk):
        router.update(np.arange(lo, min(lo + chunk, args.kb_entries)),
                      fill_vals[lo:lo + chunk])
    standbys = []
    if args.kb_replicas:
        # one warm standby per partition, filled through the router's
        # export/import stream and kept in sync by the write tee — the
        # in-process rehearsal of `serve.py --replica-of`; replicas
        # beyond the first queue as COLD spares the router fills and
        # attaches automatically when a promotion empties the slot
        for p in range(P):
            for i in range(args.kb_replicas):
                s = KnowledgeBankServer(int(pmap.counts[p]), args.kb_dim,
                                        backend=args.kb_backend,
                                        coalesce=not args.no_coalesce,
                                        reorder=args.kb_reorder,
                                        storage=args.kb_storage)
                standbys.append(s)
                if i == 0:
                    router.attach_standby(p, InProcessTransport(s),
                                          fill=True)
                else:
                    router.add_spare(p, InProcessTransport(s))
    for s in servers + standbys:
        s.warmup(args.batch * args.clients)
    router.nn_search(np.zeros((args.batch, args.kb_dim), np.float32), k=8)

    def client(t: int, n_calls: int):
        crng = np.random.default_rng(args.seed + 1 + t)
        for _ in range(n_calls):
            ids = crng.integers(0, args.kb_entries, (args.batch,))
            vals = router.lookup(ids)
            router.lazy_grad(ids, 0.01 * vals)
            router.nn_search(vals, k=8)

    threads = [threading.Thread(target=client, args=(t, args.gen))
               for t in range(args.clients)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    dt = time.perf_counter() - t0
    calls = args.clients * args.gen * 3
    stats = router.stats()
    router.close()
    for s in servers + standbys:
        s.close()
    m = stats["metrics"]
    print(f"kb-serve partitions={P} backend={args.kb_backend} "
          f"replicas={args.kb_replicas} "
          f"reorder={args.kb_reorder} clients={args.clients}: "
          f"{calls / dt:.0f} req/s ({dt / calls * 1e6:.0f} us/req), "
          f"coalescing x{stats['coalescing_factor']:.1f}, "
          f"{int(m.get('dispatches', 0))} device dispatches for "
          f"{int(m.get('requests', 0))} requests "
          f"({int(m.get('reorders', 0))} reordered), "
          f"router fast-path "
          f"{stats['router']['single_partition_fastpath']}"
          f"/{stats['router']['fanouts']} fan-outs", flush=True)
    sst = stats.get("storage", {})
    if sst:
        print(f"  fleet storage mode={sst['mode']} "
              f"bytes/row={int(sst['bytes_per_row'])} "
              f"bytes_resident={int(sst['bytes_resident'])} "
              f"cache hits/misses={int(m.get('cache_hits', 0))}"
              f"/{int(m.get('cache_misses', 0))} "
              f"tier faults/spills={int(sst.get('tier_faults', 0))}"
              f"/{int(sst.get('tier_spills', 0))}")
    for p, s in enumerate(stats["partitions"]):
        sm = s["metrics"]
        print(f"  partition {p}/{P}: {int(pmap.counts[p])} rows, "
              f"{int(sm.get('requests', 0))} requests -> "
              f"{int(sm.get('dispatches', 0))} dispatches")


def serve_kb(args) -> None:
    """Concurrent-client KB serving demo on the coalescing server."""
    from repro.core import (KnowledgeBankServer, MakerRuntime,
                            format_maker_stats)
    rng = np.random.default_rng(args.seed)
    dist = None
    if args.kb_backend == "sharded":
        from repro.launch.mesh import make_host_mesh
        dist = DistContext(mesh=make_host_mesh())
    partition_label = ""
    num_rows = args.kb_entries
    fill_ids = np.arange(args.kb_entries)
    if args.kb_join:
        # fleet-member mode: host ONLY slot I's rows of the GLOBAL bank.
        # Every member and every router computes the same ring from
        # (kb_entries, N), so sizing agrees without a config channel.
        from repro.core import PartitionMap
        try:
            idx, total = (int(x) for x in args.kb_join.split("/"))
        except ValueError:
            raise SystemExit(f"--kb-join wants I/N, got {args.kb_join!r}")
        if not (0 <= idx < total):
            raise SystemExit(f"--kb-join {args.kb_join}: index out of range")
        if not args.listen:
            raise SystemExit("--kb-join requires --listen (a fleet member "
                             "exists to serve remote routers)")
        pmap = PartitionMap(args.kb_entries, total)
        num_rows = int(pmap.counts[idx])
        partition_label = f"{idx}/{total}"
        # synthetic fill values keyed by GLOBAL id, so a partitioned
        # fleet's initial table matches a single server's row-for-row
        fill_ids = pmap.global_ids(idx)
    server = KnowledgeBankServer(num_rows, args.kb_dim,
                                 backend=args.kb_backend, dist=dist,
                                 coalesce=not args.no_coalesce,
                                 reorder=args.kb_reorder,
                                 search_mode=args.kb_search,
                                 ann_nlist=args.nlist,
                                 ann_nprobe=args.nprobe,
                                 storage=args.kb_storage,
                                 cache_rows=args.kb_cache_rows,
                                 resident_rows=args.kb_resident_rows,
                                 cold_after_rows=args.kb_cold_after,
                                 cold_dir=args.kb_cold_dir or None)
    if args.replica_of:
        # standby boot: instead of the synthetic fill, copy the primary's
        # full per-row state (every leaf, bit-identically) so this member
        # can be promoted in its place. The router re-fills on attach to
        # close the gap between this boot copy and the first teed write.
        if not args.kb_join:
            raise SystemExit("--replica-of requires --kb-join I/N (a "
                             "standby mirrors one ring slot)")
        from repro.core import SocketTransport, parse_hostport
        from repro.core.kb_protocol import (ExportRowsRequest,
                                            ImportRowsRequest)
        ph, pp = parse_hostport(args.replica_of)
        src = SocketTransport(ph, pp, expect_partition=partition_label)
        copy_chunk = 1024
        for lo in range(0, num_rows, copy_chunk):
            lids = np.arange(lo, min(lo + copy_chunk, num_rows))
            leaves = src.request(ExportRowsRequest(lids)).leaves
            server.import_rows(lids, leaves)
        src.close()
        print(f"replica boot: copied {num_rows} rows from "
              f"{args.replica_of} (slot {partition_label})", flush=True)
    else:
        all_vals = rng.normal(size=(args.kb_entries, args.kb_dim)) \
            .astype(np.float32)
        # tiered banks bound the distinct rows one write may touch —
        # chunk the initial fill to fit the resident tier
        fill_vals = all_vals[fill_ids]
        chunk = (min(args.kb_resident_rows, num_rows)
                 if args.kb_resident_rows else num_rows)
        for lo in range(0, num_rows, chunk):
            server.update(np.arange(lo, min(lo + chunk, num_rows)),
                          fill_vals[lo:lo + chunk])
    server.warmup(args.batch * args.clients)
    refresher = None
    if args.kb_search == "ivf":
        # index maker: clusters the bank off the serving path. On the
        # sharded backend this maintains one sub-index per shard and
        # rebuilds stale shards independently (repro.core.ann_index).
        refresher = server.start_ann_refresher(min_period_s=0.01)
        deadline = time.time() + 120.0
        while server.engine.ann_index is None:   # first build, then serve
            if refresher.last_error is not None or not refresher.is_alive():
                raise RuntimeError("IVF index build failed") \
                    from refresher.last_error
            if time.time() > deadline:
                raise RuntimeError("IVF index build timed out")
            time.sleep(0.01)

    # pre-compile the nn_search program too (warmup() covers only the
    # lookup/lazy_grad buckets) so no first-request jit stall is timed
    server.nn_search(np.zeros((args.batch, args.kb_dim), np.float32), k=8)

    runtime = None
    if args.kb_makers:
        # trainer-less serving can still host the checkpoint-free makers
        # (graph_builder): background engine clients maintaining the
        # dynamic neighbor graph while the bank serves. Paced (never
        # free-running): maker traffic shares the server, so an unpaced
        # maker would skew the timed client metrics below
        runtime = MakerRuntime(server, num_entries=args.kb_entries)
        for kind in args.kb_makers.split(","):
            runtime.register(kind.strip(), batch_size=args.batch,
                             min_period_s=args.kb_maker_period)
        runtime.start()

    if args.listen:
        # -- wire-serving mode: host the bank for OTHER processes ---------
        from repro.core import KBTransportServer, parse_hostport
        from repro.core.kb_protocol import PROTOCOL_VERSION
        host, port = parse_hostport(args.listen)
        transport = KBTransportServer(
            server, host, port,
            max_inflight=args.max_inflight,
            max_inflight_control=args.max_inflight_control or None,
            max_inflight_bulk=args.max_inflight_bulk or None,
            cork_us=args.cork_us, scheduler=args.scheduler,
            sock_buf=args.sock_buf, partition=partition_label)
        part = (f"partition {partition_label}, {num_rows} of "
                f"{args.kb_entries} rows, " if partition_label else "")
        print(f"kb server listening on {transport.host}:{transport.port} "
              f"(protocol v{PROTOCOL_VERSION}, backend={args.kb_backend}, "
              f"{part}bank {args.kb_entries}x{args.kb_dim}, "
              f"search={args.kb_search})", flush=True)
        stop = threading.Event()
        for sig in (signal.SIGINT, signal.SIGTERM):
            signal.signal(sig, lambda *_: stop.set())
        stop.wait(args.serve_seconds or None)
        conns = transport.connections_accepted
        wire_reqs = transport.requests_served
        sendalls = transport.sendalls
        transport.close()
        summary = (f"{conns} connections, {wire_reqs} wire requests "
                   f"({sendalls} sendalls), ")
    else:
        # -- local-driver mode: synthetic concurrent in-process clients ---
        def client(t: int, n_calls: int):
            crng = np.random.default_rng(args.seed + 1 + t)
            for _ in range(n_calls):
                ids = crng.integers(0, args.kb_entries, (args.batch,))
                vals = server.lookup(ids)
                server.lazy_grad(ids, 0.01 * vals)
                server.nn_search(vals, k=8)

        threads = [threading.Thread(target=client, args=(t, args.gen))
                   for t in range(args.clients)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        dt = time.perf_counter() - t0
        calls = args.clients * args.gen * 3
        summary = (f"clients={args.clients}: {calls / dt:.0f} req/s "
                   f"({dt / calls * 1e6:.0f} us/req), ")
    stats = dict(server.engine.search_stats)
    rebuilds = refresher.rebuilds if refresher else 0
    shard_rebuilds = refresher.shard_rebuilds if refresher else 0
    maker_stats = {}
    if runtime is not None:
        runtime.stop()
        maker_stats = server.maker_stats
    index = server.engine.ann_index
    server.close()
    print(f"kb-serve backend={args.kb_backend} search={args.kb_search} "
          f"coalesce={not args.no_coalesce} {summary}"
          f"coalescing x{server.coalescing_factor:.1f}, "
          f"{server.metrics['dispatches']} device dispatches for "
          f"{server.metrics['requests']} requests, "
          f"nn ivf/exact={stats['ivf']}/{stats['exact']}, "
          f"index rebuilds={rebuilds} ({shard_rebuilds} shard builds)",
          flush=True)
    sst = server.engine.storage_stats()
    print(f"kb storage mode={sst['mode']} bytes/row={sst['bytes_per_row']} "
          f"resident={sst['resident_rows']}/{sst['total_rows']} rows "
          f"(cold={sst['cold_rows']}), "
          f"bytes_resident={sst['bytes_resident']}, "
          f"cache hits/misses={server.metrics['cache_hits']}"
          f"/{server.metrics['cache_misses']}, "
          f"tier faults/spills={sst['tier_faults']}/{sst['tier_spills']}",
          flush=True)
    for line in format_maker_stats(maker_stats):
        print(line)
    if index is not None and hasattr(index, "shard_stats"):
        # per-shard bucket skew: cap vs mean occupancy. headroom->0 marks
        # the shard whose next rebuild forces a full repack
        for st in index.shard_stats():
            print(f"ivf shard {st['shard']}: cap={st['bucket_cap']} "
                  f"mean_occ={st['mean_occupancy']:.1f} "
                  f"max_occ={st['max_occupancy']} "
                  f"skew=x{st['skew']:.2f} headroom={st['headroom']}")
    elif index is not None:
        st = index.bucket_stats()
        print(f"ivf buckets: cap={st['bucket_cap']} "
              f"mean_occ={st['mean_occupancy']:.1f} "
              f"max_occ={st['max_occupancy']} skew=x{st['skew']:.2f} "
              f"headroom={st['headroom']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kb", action="store_true",
                    help="serve the knowledge bank instead of the LM")
    ap.add_argument("--kb-backend", choices=["dense", "pallas", "sharded"],
                    default="dense")
    ap.add_argument("--kb-entries", type=int, default=4096)
    ap.add_argument("--kb-dim", type=int, default=64)
    ap.add_argument("--kb-storage", choices=["fp32", "int8"], default="fp32",
                    help="bank row storage: fp32, or int8 codes + per-row "
                         "fp32 scale/offset with dequant fused into the "
                         "serving kernels (~3.5x less row memory)")
    ap.add_argument("--kb-cache-rows", type=int, default=0,
                    help="hot-id LRU capacity (rows) in front of the "
                         "engine; 0 disables the cache")
    ap.add_argument("--kb-resident-rows", type=int, default=None,
                    help="two-tier mode: keep only this many rows "
                         "device-resident; the rest spill to the cold "
                         "store and fault back on first touch")
    ap.add_argument("--kb-cold-after", type=int, default=None,
                    help="proactively spill rows untouched for this many "
                         "written rows (requires --kb-resident-rows)")
    ap.add_argument("--kb-cold-dir", default="",
                    help="cold-tier spill directory (default: host RAM)")
    ap.add_argument("--kb-search", choices=["exact", "ivf"], default="exact",
                    help="nn_search mode; ivf serves from the background-"
                         "clustered index (exact fallback until built)")
    ap.add_argument("--nlist", type=int, default=64,
                    help="IVF partitions (k-means centroids)")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="IVF partitions probed per query")
    ap.add_argument("--kb-autotuned", default="", metavar="PATH",
                    help="load the ANN sweep result written by "
                         "tools/autotune_ann.py and override "
                         "--nlist/--nprobe with the winning config for "
                         "the active --kb-storage mode")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--kb-makers", default="",
                    help="comma list of checkpoint-free maker kinds (e.g. "
                         "graph_builder) to run as background engine "
                         "clients while serving; their counters print "
                         "with the serve summary (their traffic shares "
                         "the server, so the timed req/s includes the "
                         "maker load)")
    ap.add_argument("--kb-maker-period", type=float, default=0.05,
                    help="pacing floor (s) for --kb-makers jobs; keeps "
                         "background makers from saturating the timed "
                         "serving window")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="per-call locked baseline (benchmark ablation)")
    ap.add_argument("--kb-partitions", type=int, default=1,
                    help="split the id space over this many in-process "
                         "partition servers behind a KBRouter and drive "
                         "the router (scale-out rehearsal; incompatible "
                         "with --listen — use --kb-join for a wire fleet)")
    ap.add_argument("--kb-join", default="", metavar="I/N",
                    help="be partition I of an N-member fleet: host only "
                         "the ring slot's rows of the GLOBAL --kb-entries "
                         "bank and label the handshake I/N (requires "
                         "--listen); routers connect all members with "
                         "--kb-connect host:p0,host:p1,... in ring order")
    ap.add_argument("--kb-replicas", type=int, default=0,
                    help="--kb-partitions: replicas per in-process "
                         "partition — the first is a warm standby attached "
                         "to the router (filled by row export/import, kept "
                         "in sync by the write tee), the rest queue as "
                         "cold spares auto-attached after a promotion; "
                         "the wire-fleet equivalent is one --replica-of "
                         "process per member")
    ap.add_argument("--replica-of", default="", metavar="HOST:PORT",
                    help="boot as the standby of the fleet member at "
                         "HOST:PORT: size to the same --kb-join ring slot, "
                         "copy its full row state (every leaf, bit-"
                         "identically), then serve — a router attaches it "
                         "with attach_standby / the host:pN|host:sbN "
                         "--kb-connect syntax and promotes it if the "
                         "primary dies")
    ap.add_argument("--kb-reorder", action="store_true",
                    help="cross-op reordering in the coalescing "
                         "dispatcher: commuting requests (disjoint-id "
                         "writes, any lookups) hoist across the queue "
                         "into bigger batched dispatches")
    ap.add_argument("--listen", default="", metavar="HOST:PORT",
                    help="expose the bank on the TCP wire protocol for "
                         "cross-process trainers/makers (port 0 = "
                         "ephemeral, printed on startup) instead of "
                         "driving synthetic local clients")
    ap.add_argument("--serve-seconds", type=float, default=0.0,
                    help="--listen: exit after this long (0 = until "
                         "SIGINT/SIGTERM)")
    ap.add_argument("--max-inflight", type=int, default=32,
                    help="--listen: pipelining credits per connection "
                         "PER LANE (unanswered requests before the reader "
                         "applies TCP backpressure)")
    ap.add_argument("--max-inflight-control", type=int, default=0,
                    help="--listen: override the control lane's credits "
                         "(0 = same as --max-inflight)")
    ap.add_argument("--max-inflight-bulk", type=int, default=0,
                    help="--listen: override the bulk lane's credits "
                         "(0 = same as --max-inflight)")
    ap.add_argument("--cork-us", type=int, default=0,
                    help="--listen: adaptive writer-side cork window in "
                         "microseconds — hold a response batch up to this "
                         "long while more responses are in flight, packing "
                         "small frames into one sendall (0 = off)")
    ap.add_argument("--scheduler", choices=("lanes", "fifo"),
                    default="lanes",
                    help="--listen: response scheduler — 'lanes' (v4 "
                         "weighted priority, control > point > bulk) or "
                         "'fifo' (v3-style arrival order, the ablation "
                         "baseline)")
    ap.add_argument("--sock-buf", type=int, default=0,
                    help="--listen: SO_SNDBUF/SO_RCVBUF bytes "
                         "(0 = OS default)")
    add_device_args(ap)
    args = ap.parse_args(argv)
    apply_device_args(args)

    if args.kb:
        if args.kb_autotuned:
            from repro.core.ann_autotune import load_autotune
            tuned = load_autotune(args.kb_autotuned,
                                  storage=args.kb_storage)
            args.kb_search = "ivf"
            args.nlist, args.nprobe = tuned["nlist"], tuned["nprobe"]
            print(f"autotuned ANN config ({args.kb_storage}): "
                  f"nlist={args.nlist} nprobe={args.nprobe} "
                  f"recall@10={tuned['recall']:.3f}", flush=True)
            if not tuned.get("meets_floor", True):
                print("WARNING: no swept config cleared the recall "
                      "floor; serving the best-recall cell anyway — "
                      "widen the autotuner grid", file=sys.stderr,
                      flush=True)
        if args.kb_replicas and args.kb_partitions <= 1:
            ap.error("--kb-replicas pairs with --kb-partitions N (wire "
                     "fleets boot standbys with --replica-of instead)")
        if args.kb_partitions > 1:
            if args.listen:
                ap.error("--kb-partitions drives an in-process router; "
                         "to expose a partitioned fleet on the wire run "
                         "one process per partition with --kb-join I/N "
                         "--listen")
            if args.kb_makers or args.kb_search == "ivf":
                ap.error("--kb-partitions supports the plain serving "
                         "drive (no --kb-makers/--kb-search ivf yet)")
            serve_kb_partitioned(args)
        else:
            serve_kb(args)
        return

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    dist = DistContext()
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    B = args.batch
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    (B, args.prompt_len)), jnp.int32)
    extra = {}
    if cfg.frontend == "vision":
        extra["patch_embs"] = jnp.zeros(
            (B, cfg.num_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio":
        extra["frames"] = jnp.zeros(
            (B, cfg.num_frontend_tokens, cfg.d_model), jnp.float32)

    t0 = time.perf_counter()
    cache, _ = model.prefill(params, toks, extra, dist,
                             cache_len=args.prompt_len + args.gen +
                             (cfg.num_frontend_tokens
                              if cfg.frontend == "vision" else 0) + 1)
    jax.block_until_ready(cache["t"])
    t_prefill = time.perf_counter() - t0

    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, extra, dist))
    last = toks[:, -1:]
    out = []
    t0 = time.perf_counter()
    for _ in range(args.gen):
        logits, cache = step(params, cache, last)
        last = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(last))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)
    print(f"arch={cfg.name} prefill({B}x{args.prompt_len})={t_prefill*1e3:.0f}ms"
          f" decode {args.gen} tok: {t_decode/args.gen*1e3:.1f} ms/tok")
    print("generated:", gen[0].tolist())


if __name__ == "__main__":
    main()
