"""Multi-process CI smoke: the cross-process CARLS topology end to end.

Boots the real deployment shapes with zero test scaffolding:

``--phase single`` (ISSUE 5 — one bank, one worker):
1. ``repro.launch.serve --kb --listen 127.0.0.1:0`` in one process
   (ephemeral port parsed from its "listening on" line),
2. ``repro.launch.maker_worker --connect`` in a second process running a
   checkpoint-free ``graph_builder`` fleet for a few steps,
3. asserts the worker reported ``rows_written > 0`` and exited 0,
4. SIGTERMs the server and asserts it printed its serving summary with a
   non-zero wire-request count, and exited 0.

``--phase router`` (ISSUE 6 — the partitioned fleet):
1. TWO ``serve --kb --kb-join i/2 --listen 127.0.0.1:0`` processes, each
   hosting its consistent-hash slice of one 256-row bank,
2. a ``connect_kb("host:p0,host:p1")`` client process that updates rows it
   KNOWS live on different partitions, reads them back, and runs an
   nn_search whose result set must span both partitions,
3. ``maker_worker --connect host:p0,host:p1`` — the unchanged worker
   routed transparently through a ``KBRouter`` — with rows_written > 0,
4. SIGTERMs both members and asserts EACH served wire requests > 0 (both
   partitions took traffic, none sat idle behind the router).

``--phase mixed`` (ISSUE 10 — the v4 multiplexed wire):
1. one ``serve --kb --listen`` bank process,
2. a client process sharing ONE pipelined connection between nn_search
   hog threads and a point-lookup thread (the workload FIFO response
   matching head-of-line-blocked before v4),
3. asserts zero client errors and a generous absolute lookup-p99 bound —
   a v3-style delivery stall parks lookups behind every in-flight bulk
   search and blows the bound; bit-identity is the bench's job
   (``kb_serving/mixed/*``), this phase proves the real-process path.

``--phase failover`` (ISSUE 8 — the self-healing fleet):
1. TWO partition members plus ONE standby (``serve --kb-join 0/2
   --replica-of host:p0``) that boot-copies its primary's rows,
2. a ``maker_worker --connect host:p0|host:s0,host:p1`` fleet; the moment
   it reports connected, member p0 is SIGKILLed — so essentially every
   maker step runs against the killed fleet,
3. asserts the worker still finished ALL its steps with zero errors and
   rows_written > 0: its router promoted the standby mid-run,
4. SIGTERMs the survivor and the promoted standby and asserts each served
   wire traffic.

Usage:
  python tools/smoke_multiproc.py [--phase single|router|mixed|failover|all]
(exit 0 = pass)
"""
from __future__ import annotations

import argparse
import os
import re
import select
import signal
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
STARTUP_TIMEOUT_S = 300         # cold jax import + jit warmup on CI

# runs inside a client subprocess (needs the repro jax stack, which the
# driver itself never imports): prove the router splits writes/reads
# across both fleet members and merges nn results across them
_ROUTER_CLIENT = r"""
import sys
import numpy as np
from repro.core import connect_kb
from repro.core.kb_router import PartitionMap

kb = connect_kb(sys.argv[1], client_name="smoke-router")
pmap = PartitionMap(kb.num_entries, 2)
ids = np.array([int(pmap.global_ids(0)[0]), int(pmap.global_ids(1)[0])])
vals = np.eye(2, kb.dim, dtype=np.float32) * 100.0
kb.update(ids, vals)                      # one row on EACH partition
back = kb.lookup(ids)
assert np.allclose(back, vals), "cross-partition lookup mismatch"
scores, nn = kb.nn_search(vals, k=1)      # each planted row dominates its
owners = set(int(o) for o in pmap.owner_of(nn[:, 0]))   # own query
assert nn[0, 0] == ids[0] and nn[1, 0] == ids[1], (nn, ids)
assert owners == {0, 1}, f"nn results stayed on partitions {owners}"
kb.close()
print("router-client OK")
"""


# mixed workload over one connection: bulk nn_search hogs + a timed point
# lookup thread. argv: spec, p99 bound in ms. Prints the measured p99 and
# an error count that must be zero.
_MIXED_CLIENT = r"""
import sys, threading, time
import numpy as np
from repro.core import connect_kb

spec, bound_ms = sys.argv[1], float(sys.argv[2])
kb = connect_kb(spec, client_name="smoke-mixed")
n = kb.num_entries
table = np.random.default_rng(0).normal(size=(64, kb.dim)) \
    .astype(np.float32)
kb.lookup(np.arange(16)); kb.nn_search(table[:16], 4)      # warm the wire
errors, lat = [], []
done = threading.Event()

def hog(h):
    rng = np.random.default_rng(40 + h)
    while not done.is_set():
        try:
            kb.nn_search(table[rng.integers(0, 64, (32,))], 8)
        except Exception as e:
            errors.append(e)
            return

def looker():
    rng = np.random.default_rng(99)
    try:
        for _ in range(80):
            ids = rng.integers(0, n, (16,))
            t0 = time.perf_counter()
            kb.lookup(ids)
            lat.append(time.perf_counter() - t0)
    except Exception as e:
        errors.append(e)
    finally:
        done.set()

hogs = [threading.Thread(target=hog, args=(h,)) for h in range(3)]
for t in hogs: t.start()
time.sleep(0.05)
lt = threading.Thread(target=looker)
lt.start(); lt.join()
for t in hogs: t.join()
st = kb.stats()["transport"]
kb.close()
p99 = float(np.percentile(np.asarray(lat), 99) * 1e3)
assert not errors, f"client errors: {errors[:3]}"
assert p99 <= bound_ms, f"lookup p99 {p99:.1f}ms over {bound_ms}ms bound"
print(f"mixed-client OK p99={p99:.2f}ms errors=0 "
      f"reissued={st['reissued']}")
"""


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _boot_server(extra_args):
    """Start a serve.py bank process and return (proc, port) once its
    "listening on" line appears — select-with-deadline, NOT a bare
    readline: a server that wedges before printing anything must fail at
    the startup budget, not at the CI job timeout with zero diagnostics."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--kb",
         "--kb-entries", "256", "--kb-dim", "32",
         "--listen", "127.0.0.1:0", "--serve-seconds", "600", *extra_args],
        env=_env(), cwd=ROOT, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    lines = []
    deadline = time.time() + STARTUP_TIMEOUT_S
    while True:
        if time.time() > deadline:
            raise RuntimeError("server never reported listening within "
                               f"{STARTUP_TIMEOUT_S}s:\n" + "".join(lines))
        ready, _, _ = select.select([proc.stdout], [], [], 5.0)
        if not ready:
            if proc.poll() is not None:
                raise RuntimeError(
                    f"server exited early:\n{''.join(lines)}")
            continue
        line = proc.stdout.readline()
        if not line:
            raise RuntimeError(f"server exited early:\n{''.join(lines)}")
        lines.append(line)
        print("[serve]", line, end="", flush=True)
        m = re.search(r"listening on [\d.]+:(\d+)", line)
        if m:
            return proc, int(m.group(1))


def _stop_server(proc, name):
    """SIGTERM, collect the summary, assert a clean exit that actually
    served wire traffic."""
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=120)
    print(f"[{name}]", out, flush=True)
    if proc.returncode != 0:
        raise RuntimeError(f"{name} exited {proc.returncode}")
    m = re.search(r"(\d+) wire requests", out)
    if not m or int(m.group(1)) <= 0:
        raise RuntimeError(f"{name} served no wire requests")


def _run_worker(connect_spec):
    worker = subprocess.run(
        [sys.executable, "-m", "repro.launch.maker_worker",
         "--connect", connect_spec,
         "--makers", "graph_builder", "--steps", "5", "--batch", "16"],
        env=_env(), cwd=ROOT, capture_output=True, text=True,
        timeout=STARTUP_TIMEOUT_S)
    print("[worker]", worker.stdout, worker.stderr, flush=True)
    if worker.returncode != 0:
        raise RuntimeError(f"worker exited {worker.returncode}")
    m = re.search(r"rows_written=(\d+)", worker.stdout)
    if not m or int(m.group(1)) <= 0:
        raise RuntimeError("worker reported no rows_written")


def phase_single() -> None:
    serve, port = _boot_server([])
    try:
        _run_worker(f"127.0.0.1:{port}")
        _stop_server(serve, "serve")
    finally:
        if serve.poll() is None:
            serve.kill()
    print("single-server smoke: OK", flush=True)


def phase_router() -> None:
    members = []
    try:
        for i in range(2):
            members.append(_boot_server(["--kb-join", f"{i}/2"]))
        spec = ",".join(f"127.0.0.1:{port}" for _, port in members)

        client = subprocess.run(
            [sys.executable, "-c", _ROUTER_CLIENT, spec],
            env=_env(), cwd=ROOT, capture_output=True, text=True,
            timeout=STARTUP_TIMEOUT_S)
        print("[client]", client.stdout, client.stderr, flush=True)
        if client.returncode != 0 or "router-client OK" not in client.stdout:
            raise RuntimeError(f"router client failed ({client.returncode})")

        _run_worker(spec)
        for i, (proc, _) in enumerate(members):
            _stop_server(proc, f"serve-p{i}")
    finally:
        for proc, _ in members:
            if proc.poll() is None:
                proc.kill()
    print("router smoke: OK", flush=True)


def phase_mixed() -> None:
    serve, port = _boot_server([])
    try:
        client = subprocess.run(
            [sys.executable, "-c", _MIXED_CLIENT,
             f"127.0.0.1:{port}", "2000"],
            env=_env(), cwd=ROOT, capture_output=True, text=True,
            timeout=STARTUP_TIMEOUT_S)
        print("[client]", client.stdout, client.stderr, flush=True)
        if client.returncode != 0 or "mixed-client OK" not in client.stdout:
            raise RuntimeError(f"mixed client failed ({client.returncode})")
        _stop_server(serve, "serve")
    finally:
        if serve.poll() is None:
            serve.kill()
    print("mixed smoke: OK", flush=True)


def phase_failover() -> None:
    procs = []
    worker = None
    try:
        p0 = _boot_server(["--kb-join", "0/2"])
        procs.append(p0)
        p1 = _boot_server(["--kb-join", "1/2"])
        procs.append(p1)
        s0 = _boot_server(["--kb-join", "0/2",
                           "--replica-of", f"127.0.0.1:{p0[1]}"])
        procs.append(s0)
        spec = (f"127.0.0.1:{p0[1]}|127.0.0.1:{s0[1]},"
                f"127.0.0.1:{p1[1]}")
        worker = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.maker_worker",
             "--connect", spec, "--makers", "graph_builder",
             "--steps", "20", "--batch", "16",
             "--seconds", str(STARTUP_TIMEOUT_S),
             "--max-retries", "1", "--reconnect-backoff", "0.01"],
            env=_env(), cwd=ROOT, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True)
        # kill the primary the moment the worker is connected (before its
        # makers start): every step must then ride the promoted standby
        lines = []
        deadline = time.time() + STARTUP_TIMEOUT_S
        while True:
            if time.time() > deadline:
                raise RuntimeError("worker never connected:\n"
                                   + "".join(lines))
            line = worker.stdout.readline()
            if not line:
                raise RuntimeError("worker exited before connecting:\n"
                                   + "".join(lines))
            lines.append(line)
            print("[worker]", line, end="", flush=True)
            if "maker-worker connected" in line:
                break
        p0[0].send_signal(signal.SIGKILL)
        p0[0].wait(timeout=60)
        print("[driver] SIGKILLed member p0; worker must promote s0",
              flush=True)
        out, _ = worker.communicate(timeout=STARTUP_TIMEOUT_S)
        print("[worker]", out, flush=True)
        if worker.returncode != 0:
            raise RuntimeError(f"worker exited {worker.returncode} after "
                               "the primary was killed")
        m = re.search(r"done: steps=(\d+) rows_written=(\d+) errors=(\d+)",
                      out)
        if not m:
            raise RuntimeError("worker printed no final report")
        steps, rows, errors = (int(g) for g in m.groups())
        if steps < 20 or rows <= 0 or errors > 0:
            raise RuntimeError(
                f"maker did not keep advancing through fail-over: "
                f"steps={steps} rows_written={rows} errors={errors}")
        _stop_server(p1[0], "serve-p1")
        _stop_server(s0[0], "serve-s0")     # the PROMOTED member
    finally:
        if worker is not None and worker.poll() is None:
            worker.kill()
        for proc, _ in procs:
            if proc.poll() is None:
                proc.kill()
    print("failover smoke: OK", flush=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--phase",
                    choices=["single", "router", "mixed", "failover",
                             "all"],
                    default="all")
    args = ap.parse_args()
    if args.phase in ("single", "all"):
        phase_single()
    if args.phase in ("router", "all"):
        phase_router()
    if args.phase in ("mixed", "all"):
        phase_mixed()
    if args.phase in ("failover", "all"):
        phase_failover()
    print("multi-process smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
