"""Multi-process CI smoke: the cross-process CARLS topology end to end.

Boots the real deployment shape with zero test scaffolding:

1. ``repro.launch.serve --kb --listen 127.0.0.1:0`` in one process
   (ephemeral port parsed from its "listening on" line),
2. ``repro.launch.maker_worker --connect`` in a second process running a
   checkpoint-free ``graph_builder`` fleet for a few steps,
3. asserts the worker reported ``rows_written > 0`` and exited 0,
4. SIGTERMs the server and asserts it printed its serving summary with a
   non-zero wire-request count, and exited 0.

Usage:  python tools/smoke_multiproc.py     (exit 0 = pass)
"""
from __future__ import annotations

import os
import re
import select
import signal
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
STARTUP_TIMEOUT_S = 300         # cold jax import + jit warmup on CI


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def main() -> int:
    serve = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--kb",
         "--kb-entries", "256", "--kb-dim", "32",
         "--listen", "127.0.0.1:0", "--serve-seconds", "600"],
        env=_env(), cwd=ROOT, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    port = None
    serve_lines = []
    try:
        deadline = time.time() + STARTUP_TIMEOUT_S
        # select-with-deadline, NOT a bare readline: a server that wedges
        # before printing anything must fail here at the startup budget,
        # not at the CI job timeout with zero diagnostics
        while port is None:
            if time.time() > deadline:
                raise RuntimeError("server never reported listening "
                                   f"within {STARTUP_TIMEOUT_S}s:\n"
                                   + "".join(serve_lines))
            ready, _, _ = select.select([serve.stdout], [], [], 5.0)
            if not ready:
                if serve.poll() is not None:
                    raise RuntimeError(
                        f"server exited early:\n{''.join(serve_lines)}")
                continue
            line = serve.stdout.readline()
            if not line:
                raise RuntimeError(
                    f"server exited early:\n{''.join(serve_lines)}")
            serve_lines.append(line)
            print("[serve]", line, end="", flush=True)
            m = re.search(r"listening on [\d.]+:(\d+)", line)
            if m:
                port = int(m.group(1))

        worker = subprocess.run(
            [sys.executable, "-m", "repro.launch.maker_worker",
             "--connect", f"127.0.0.1:{port}",
             "--makers", "graph_builder", "--steps", "5", "--batch", "16"],
            env=_env(), cwd=ROOT, capture_output=True, text=True,
            timeout=STARTUP_TIMEOUT_S)
        print("[worker]", worker.stdout, worker.stderr, flush=True)
        if worker.returncode != 0:
            raise RuntimeError(f"worker exited {worker.returncode}")
        m = re.search(r"rows_written=(\d+)", worker.stdout)
        if not m or int(m.group(1)) <= 0:
            raise RuntimeError("worker reported no rows_written")

        serve.send_signal(signal.SIGTERM)
        out, _ = serve.communicate(timeout=120)
        print("[serve]", out, flush=True)
        if serve.returncode != 0:
            raise RuntimeError(f"server exited {serve.returncode}")
        m = re.search(r"(\d+) wire requests", out)
        if not m or int(m.group(1)) <= 0:
            raise RuntimeError("server served no wire requests")
    finally:
        if serve.poll() is None:
            serve.kill()
    print("multi-process smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
