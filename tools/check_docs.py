"""Docs CI: keep README/docs honest.

Two checks, zero dependencies:

1. **Snippet execution** — every fenced ```python block in README.md and
   docs/*.md is extracted and executed via ``python -c`` with
   ``PYTHONPATH=src`` from the repo root. Doc code that drifts from the
   API fails CI, not a reader. (Shell examples use ```bash and are not
   executed; illustrative non-runnable text uses ```text.)
2. **Link check** — every relative markdown link in README.md, docs/,
   and ROADMAP.md must resolve to an existing file (anchors stripped;
   http(s)/mailto links skipped — no network in CI).

Usage:  python tools/check_docs.py
Exit code 0 = all snippets ran and all links resolve.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SNIPPET_FILES = ["README.md"]
LINK_FILES = ["README.md", "ROADMAP.md"]
for name in sorted(os.listdir(os.path.join(ROOT, "docs"))):
    if name.endswith(".md"):
        SNIPPET_FILES.append(os.path.join("docs", name))
        LINK_FILES.append(os.path.join("docs", name))

FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
# [text](target) — ignore images' leading ! (same target rules apply)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def run_snippets() -> int:
    failures = 0
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    for rel in SNIPPET_FILES:
        text = open(os.path.join(ROOT, rel)).read()
        for i, m in enumerate(FENCE_RE.finditer(text)):
            code = m.group(1)
            r = subprocess.run([sys.executable, "-c", code], env=env,
                               cwd=ROOT, capture_output=True, text=True,
                               timeout=600)
            tag = f"{rel} python block #{i + 1}"
            if r.returncode != 0:
                failures += 1
                print(f"FAIL {tag}\n{r.stdout}{r.stderr}", file=sys.stderr)
            else:
                print(f"ok   {tag}")
    return failures


def check_links() -> int:
    failures = 0
    for rel in LINK_FILES:
        path = os.path.join(ROOT, rel)
        text = open(path).read()
        base = os.path.dirname(path)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            resolved = os.path.normpath(
                os.path.join(base, target.split("#")[0]))
            if not os.path.exists(resolved):
                failures += 1
                print(f"FAIL {rel}: broken link -> {target}",
                      file=sys.stderr)
        print(f"ok   {rel} links")
    return failures


def main() -> int:
    bad = run_snippets() + check_links()
    if bad:
        print(f"{bad} doc check(s) failed", file=sys.stderr)
        return 1
    print("docs ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
