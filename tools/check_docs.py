"""Docs CI: keep README/docs honest.

Three checks, zero dependencies:

1. **Snippet execution** — every fenced ```python block in README.md and
   docs/*.md is extracted and executed via ``python -c`` with
   ``PYTHONPATH=src`` from the repo root. Doc code that drifts from the
   API fails CI, not a reader. (Shell examples use ```bash and are not
   executed; illustrative non-runnable text uses ```text.)
2. **Link check** — every relative markdown link in README.md, docs/,
   and ROADMAP.md must resolve to an existing file (anchors stripped;
   http(s)/mailto links skipped — no network in CI).
3. **Bench-key guard** — the README results table is regenerated from
   ``BENCH_nn_search.json``; the keys it relies on must stay present in
   whatever ``benchmarks/nn_search_bench.py`` emits. Runs when the file
   exists (CI runs it right after the quick-bench step writes one);
   ``--bench`` runs ONLY this check and fails if the file is missing.

Usage:  python tools/check_docs.py [--bench]
Exit code 0 = all selected checks pass.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BENCH_JSON = "BENCH_nn_search.json"
# what README.md's results table is built from: per-size timing/recall
# pairs plus the sharded section. Renaming any of these in
# benchmarks/nn_search_bench.py silently orphans the README numbers.
BENCH_TOP_KEYS = ("rows", "config", "sizes", "sharded", "skew", "autotuned")
BENCH_SIZE_KEYS = ("nlist", "nprobe", "us_exact_ref", "us_ivf_ref",
                   "us_build", "recall_at_10", "ivf_speedup_vs_exact",
                   "us_ivf_int8", "recall_at_10_int8")
BENCH_SHARDED_KEYS = ("n_shards", "us_sharded_exact", "us_sharded_ivf",
                      "recall_at_10", "ivf_speedup_vs_sharded_exact")
BENCH_SKEW_KEYS = ("N", "nlist", "occ_min", "occ_max", "chunks_padded",
                   "chunks_occupied", "work_ratio", "identical")
BENCH_AUTOTUNE_KEYS = ("nlist", "nprobe", "recall", "search_s",
                       "meets_floor")

# the scale-out serving numbers docs/tuning.md quotes; the file is only
# written by a local `benchmarks.run --only kb_serving` (CI's quick bench
# doesn't run the suite), so this guard fires only when it is present
SERVING_JSON = "BENCH_kb_serving.json"
SERVING_TOP_KEYS = ("rows", "config", "storage", "cold_tier", "scaleout",
                    "reorder", "mixed")
SERVING_SCALE_KEYS = ("partitions", "lookups_per_s", "nn_p50_us",
                      "speedup_vs_1p")
SERVING_REORDER_KEYS = ("fifo_s", "reorder_s", "speedup", "reorders",
                        "bit_identical")
SERVING_STORAGE_KEYS = ("fp32", "int8", "bytes_per_row_ratio",
                        "lookup_slowdown_int8", "ivf_recall_at_10")
SERVING_COLD_KEYS = ("total_rows", "resident_rows", "oversubscription",
                     "bytes_resident", "cold_rows", "tier_faults",
                     "tier_spills", "lookups_correct")
# the protocol-v4 mixed-workload rows (ISSUE 10) docs/architecture.md and
# docs/tuning.md quote; the per-scheduler latency dicts must keep both
# the fifo ablation and the v4 lanes entries
SERVING_MIXED_KEYS = ("hogs", "look_calls", "lookup_p99_ms",
                      "lookup_p50_ms", "p99_improvement", "bit_identical")

SNIPPET_FILES = ["README.md"]
LINK_FILES = ["README.md", "ROADMAP.md"]
for name in sorted(os.listdir(os.path.join(ROOT, "docs"))):
    if name.endswith(".md"):
        SNIPPET_FILES.append(os.path.join("docs", name))
        LINK_FILES.append(os.path.join("docs", name))

FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
# [text](target) — ignore images' leading ! (same target rules apply)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def run_snippets() -> int:
    failures = 0
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    for rel in SNIPPET_FILES:
        text = open(os.path.join(ROOT, rel)).read()
        for i, m in enumerate(FENCE_RE.finditer(text)):
            code = m.group(1)
            r = subprocess.run([sys.executable, "-c", code], env=env,
                               cwd=ROOT, capture_output=True, text=True,
                               timeout=600)
            tag = f"{rel} python block #{i + 1}"
            if r.returncode != 0:
                failures += 1
                print(f"FAIL {tag}\n{r.stdout}{r.stderr}", file=sys.stderr)
            else:
                print(f"ok   {tag}")
    return failures


def check_links() -> int:
    failures = 0
    for rel in LINK_FILES:
        path = os.path.join(ROOT, rel)
        text = open(path).read()
        base = os.path.dirname(path)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            resolved = os.path.normpath(
                os.path.join(base, target.split("#")[0]))
            if not os.path.exists(resolved):
                failures += 1
                print(f"FAIL {rel}: broken link -> {target}",
                      file=sys.stderr)
        print(f"ok   {rel} links")
    return failures


def check_bench_keys(required: bool = False) -> int:
    """README's results table references BENCH_nn_search.json fields; a
    bench rewrite that drops/renames them must fail CI, not a reader."""
    path = os.path.join(ROOT, BENCH_JSON)
    if not os.path.exists(path):
        if required:
            print(f"FAIL {BENCH_JSON} missing (run benchmarks/run.py "
                  "--only nn_search_bench first)", file=sys.stderr)
            return 1
        print(f"skip {BENCH_JSON} (not present; quick-bench CI runs this "
              "check after generating it)")
        return 0
    with open(path) as f:
        data = json.load(f)
    failures = 0

    def need(obj, keys, where):
        nonlocal failures
        for k in keys:
            if k not in obj:
                failures += 1
                print(f"FAIL {BENCH_JSON}: missing key {where}.{k} "
                      "(referenced by the README results table)",
                      file=sys.stderr)

    need(data, BENCH_TOP_KEYS, "$")
    if not data.get("sizes"):
        failures += 1
        print(f"FAIL {BENCH_JSON}: 'sizes' is empty", file=sys.stderr)
    for n, size in data.get("sizes", {}).items():
        need(size, BENCH_SIZE_KEYS, f"sizes[{n}]")
    need(data.get("sharded", {}), BENCH_SHARDED_KEYS, "sharded")
    need(data.get("skew", {}), BENCH_SKEW_KEYS, "skew")
    # docs quote the autotuned fp32 winner and its recall floor; both
    # storage winners must carry the same operating-point fields
    for mode in ("fp32", "int8"):
        need(data.get("autotuned", {}).get(mode, {}),
             BENCH_AUTOTUNE_KEYS, f"autotuned.{mode}")
    if not failures:
        print(f"ok   {BENCH_JSON} keys")
    return failures


def check_serving_keys() -> int:
    """Same guard for BENCH_kb_serving.json (scale-out rows + reorder
    comparison) — validated only when present, never required."""
    path = os.path.join(ROOT, SERVING_JSON)
    if not os.path.exists(path):
        print(f"skip {SERVING_JSON} (not present; written by "
              "benchmarks.run --only kb_serving)")
        return 0
    with open(path) as f:
        data = json.load(f)
    failures = 0

    def need(obj, keys, where):
        nonlocal failures
        for k in keys:
            if k not in obj:
                failures += 1
                print(f"FAIL {SERVING_JSON}: missing key {where}.{k} "
                      "(referenced by docs/tuning.md)", file=sys.stderr)

    need(data, SERVING_TOP_KEYS, "$")
    if not data.get("scaleout"):
        failures += 1
        print(f"FAIL {SERVING_JSON}: 'scaleout' is empty", file=sys.stderr)
    for i, row in enumerate(data.get("scaleout", [])):
        need(row, SERVING_SCALE_KEYS, f"scaleout[{i}]")
    need(data.get("reorder", {}), SERVING_REORDER_KEYS, "reorder")
    need(data.get("storage", {}), SERVING_STORAGE_KEYS, "storage")
    for mode in ("fp32", "int8"):
        need(data.get("storage", {}).get(mode, {}),
             ("bytes_per_row", "bytes_resident", "lookups_per_s"),
             f"storage.{mode}")
    need(data.get("cold_tier", {}), SERVING_COLD_KEYS, "cold_tier")
    need(data.get("mixed", {}), SERVING_MIXED_KEYS, "mixed")
    for sched in ("fifo", "lanes"):
        for metric in ("lookup_p99_ms", "lookup_p50_ms"):
            need(data.get("mixed", {}).get(metric, {}), (sched,),
                 f"mixed.{metric}")
    mixed_rows = {r.get("name") for r in data.get("rows", [])}
    for name in ("kb_serving/mixed/fifo", "kb_serving/mixed/v4-lanes"):
        if name not in mixed_rows:
            failures += 1
            print(f"FAIL {SERVING_JSON}: missing row {name!r} "
                  "(referenced by docs/tuning.md)", file=sys.stderr)
    if not failures:
        print(f"ok   {SERVING_JSON} keys")
    return failures


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--bench" in argv:
        bad = check_bench_keys(required=True) + check_serving_keys()
    else:
        bad = (run_snippets() + check_links() + check_bench_keys()
               + check_serving_keys())
    if bad:
        print(f"{bad} doc check(s) failed", file=sys.stderr)
        return 1
    print("docs ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
