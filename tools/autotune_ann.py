#!/usr/bin/env python
"""ANN autotuner CLI: sweep (nlist, nprobe) x (fp32, int8) on a synthetic
clustered bank, print the sweep table, and write the winning configs to a
JSON artifact `serve.py --kb-autotuned` consumes.

  PYTHONPATH=src python tools/autotune_ann.py --out autotune_ann.json
  PYTHONPATH=src python tools/autotune_ann.py --quick --out /tmp/tune.json
  PYTHONPATH=src python -m repro.launch.serve --kb --kb-search ivf \
      --kb-autotuned autotune_ann.json

The sweep measures recall@k against the exact fp32 top-k and picks the
lowest-latency config clearing --recall-floor per storage mode (see
repro.core.ann_autotune). --quick shrinks the sweep for CI smoke runs.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _int_list(s: str):
    return tuple(int(x) for x in s.split(",") if x.strip())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16384,
                    help="bank rows (synthetic clustered bank)")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--centers", type=int, default=64,
                    help="true clusters in the synthetic bank")
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--nlist", default="32,64,128", type=_int_list,
                    help="comma list of nlist values to sweep")
    ap.add_argument("--nprobe", default="4,8,16", type=_int_list,
                    help="comma list of nprobe values to sweep")
    ap.add_argument("--recall-floor", type=float, default=0.95)
    ap.add_argument("--iters", type=int, default=8,
                    help="k-means iteration ceiling per build")
    ap.add_argument("--out", default="autotune_ann.json",
                    help="JSON artifact path (serve.py --kb-autotuned)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep for CI smoke (small bank, 2x2 grid)")
    ap.add_argument("--seed", type=int, default=0)
    from repro.env import add_device_args, apply_device_args
    add_device_args(ap)
    args = ap.parse_args(argv)
    apply_device_args(args)

    if args.quick:
        args.n = min(args.n, 2048)
        args.queries = min(args.queries, 64)
        args.nlist = args.nlist[:2]
        args.nprobe = args.nprobe[:2]

    from repro.core.ann_autotune import save_autotune, sweep_ann
    from repro.core.ann_index import clustered_bank
    bank = clustered_bank(args.n, args.dim, args.centers, seed=args.seed)
    queries = clustered_bank(args.queries, args.dim, args.centers,
                             seed=args.seed + 1)
    result = sweep_ann(bank, queries, k=args.k, nlists=args.nlist,
                       nprobes=args.nprobe,
                       recall_floor=args.recall_floor, iters=args.iters)
    print(f"ANN sweep: bank {args.n}x{args.dim}, {args.queries} queries, "
          f"recall@{args.k} floor {args.recall_floor}")
    for r in result["results"]:
        print(f"  {r['storage']:>4} nlist={r['nlist']:>4} "
              f"nprobe={r['nprobe']:>3} cap={r['bucket_cap']:>4} "
              f"shortlist={r['shortlist_rows']:>5} "
              f"recall={r['recall']:.3f} "
              f"search={r['search_s'] * 1e3:.2f}ms "
              f"build={r['build_s'] * 1e3:.0f}ms")
    for storage, win in result["best"].items():
        floor = "" if win["meets_floor"] else "  (BELOW FLOOR: best recall)"
        print(f"best[{storage}]: nlist={win['nlist']} "
              f"nprobe={win['nprobe']} recall={win['recall']:.3f} "
              f"search={win['search_s'] * 1e3:.2f}ms{floor}")
    save_autotune(result, args.out)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
