"""Cross-process Knowledge Bank in one script: the wire protocol seam.

Stands up the real multi-process topology on loopback TCP — a
KnowledgeBankServer exposed by KBTransportServer, a RemoteKnowledgeBank
client, and a knowledge-maker fleet that only ever sees the client
duck-type — then demonstrates the three properties the seam guarantees:

1. parity     : the same op sequence over the wire and over the zero-copy
                in-process transport returns bit-identical results;
2. coalescing : wire requests merge into the SAME batched device
                dispatches as in-process callers' (one queue, one window);
3. isolation  : hanging up a client (even mid-traffic) costs the bank one
                connection — other clients never notice.

For the actual separate-OS-process deployment, see the README quickstart:
``launch/serve.py --kb --listen`` + ``launch/maker_worker.py --connect``
+ ``launch/train.py --kb-connect``.

Run:  PYTHONPATH=src python examples/remote_bank.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (InProcessTransport, KBTransportServer,
                        KnowledgeBankServer, MakerRuntime,
                        RemoteKnowledgeBank, format_maker_stats)

N, D = 1024, 32


def main():
    rng = np.random.default_rng(0)
    table = rng.normal(size=(N, D)).astype(np.float32)

    with KnowledgeBankServer(N, D, coalesce_window_s=0.002) as server:
        server.update(np.arange(N), table)
        server.warmup(256)
        with KBTransportServer(server) as ts:
            print(f"bank on 127.0.0.1:{ts.port} "
                  f"(wire protocol, no pickle)")

            # 1. parity: wire answers == zero-copy in-process answers
            wire = RemoteKnowledgeBank("127.0.0.1", ts.port,
                                       client_name="example")
            local = RemoteKnowledgeBank(InProcessTransport(server))
            q = table[:8]
            s_w, i_w = wire.nn_search(q, 8,
                                      exclude_ids=np.arange(8)[:, None])
            s_l, i_l = local.nn_search(q, 8,
                                       exclude_ids=np.arange(8)[:, None])
            assert (i_w == i_l).all() and (s_w == s_l).all()
            print("parity: wire nn_search == in-process nn_search "
                  "(bit-identical)")

            # 2. the maker fleet holds only the client duck-type; its
            # traffic coalesces with the local lookups below
            rt = MakerRuntime(wire, builder_k=8)    # geometry via handshake
            job = rt.register("graph_builder", batch_size=64)
            rt.start()
            t0 = time.perf_counter()
            for step in range(50):
                server.lookup(rng.integers(0, N, 32), trainer_step=step)
            while job.steps < 5:
                time.sleep(0.01)
            rt.stop()
            dt = time.perf_counter() - t0
            m = server.metrics
            print(f"coalescing: {m['requests']} requests "
                  f"({job.steps} maker steps over the wire + 50 local "
                  f"lookups) -> {m['dispatches']} device dispatches "
                  f"(x{server.coalescing_factor:.1f}, longest merged run "
                  f"{m['max_run']}) in {dt*1e3:.0f} ms")
            for line in format_maker_stats(wire.maker_stats):
                print(line)

            # 3. crash isolation: this client hangs up; the bank serves on
            wire.close()
            v = server.lookup(np.arange(4))
            assert v.shape == (4, D)
            print("isolation: client hung up, bank still serving "
                  f"({ts.connections_accepted} connections accepted, "
                  f"{ts.requests_served} wire requests served)")


if __name__ == "__main__":
    main()
