"""CARLS quickstart: asynchronous graph-regularized semi-supervised training
(paper Fig. 1 + §4.1) on one host.

Components wired together:
- Model Trainer  : tiny llama-style LM + graph regularizer (main thread)
- Knowledge Maker: 2 daemon threads re-encoding nodes with the latest
                   checkpoint and pushing embeddings
- Knowledge Bank : request-coalescing server over the pluggable KB engine
                   (concurrent trainer+maker calls merge into one jitted
                   batched device op per queue drain; lazy gradient updates
                   applied on next lookup)

Run:  PYTHONPATH=src python examples/quickstart.py [dense|pallas]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.core import run_async_training
from repro.data import SyntheticGraphCorpus
from repro.models import build_model


def main():
    kb_backend = sys.argv[1] if len(sys.argv) > 1 else "dense"
    cfg = get_config("yi-6b").reduced().replace(num_layers=2)
    model = build_model(cfg)
    corpus = SyntheticGraphCorpus(
        num_nodes=1024, vocab_size=cfg.vocab_size, seq_len=33,
        num_clusters=8, neighbors_per_node=cfg.carls.num_neighbors)

    print(f"=== CARLS async training: trainer + 2 knowledge makers "
          f"(kb engine: {kb_backend}) ===")
    res = run_async_training(model, corpus, steps=60, batch_size=16,
                             num_makers=2, maker_batch=64, ckpt_period=5,
                             lr=2e-3, seed=0, kb_backend=kb_backend)
    print(f"loss: {res.losses[0]:.4f} -> {np.mean(res.losses[-5:]):.4f}")
    print(f"graph-reg: {res.reg_losses[0]:.4f} -> "
          f"{np.mean(res.reg_losses[-5:]):.4f}")
    print(f"maker refreshes (concurrent with training): "
          f"{res.maker_refreshes}")
    print(f"mean embedding staleness (trainer steps): "
          f"{res.mean_staleness:.2f}")
    print(f"mean trainer step: {np.mean(res.step_times[2:])*1e3:.1f} ms "
          f"(independent of maker load — that's the point)")
    m = res.server.metrics
    print(f"kb server: {m['requests']} requests -> {m['dispatches']} device "
          f"dispatches (coalescing x{res.server.coalescing_factor:.1f}, "
          f"longest merged run {m['max_run']})")

    # the bank now holds model-space node embeddings; same-cluster nodes
    # should be closer than cross-cluster ones
    tbl = res.server.table_snapshot()
    c = corpus.clusters
    same = np.einsum("id,id->i", tbl[corpus.neighbor_table[:, 0]], tbl)
    rng = np.random.default_rng(0)
    rand = np.einsum("id,id->i",
                     tbl[rng.integers(0, corpus.num_nodes, corpus.num_nodes)],
                     tbl)
    print(f"avg similarity to graph neighbor: {same.mean():.4f}  "
          f"to random node: {rand.mean():.4f}")


if __name__ == "__main__":
    main()
