"""Asynchronous semi-supervised CARLS (paper §4.2, end to end): label
mining + graph agreement running as BACKGROUND engine clients while the
trainer's graph regularizer consumes the same Knowledge Bank.

The CARLS triangle, all three corners live at once:

- Model Trainer (main thread): graph-regularized LM steps; pushes its own
  pooled sample embeddings to the bank each step (trainer_push) and hands
  neighbor-embedding gradients to the server's lazy cache — the graph
  regularizer is fed by bank rows the makers keep fresh.
- Knowledge Makers (MakerRuntime threads): ``embedding_refresh`` keeps
  the bank aligned with the latest checkpoint; ``label_mining`` (§4.2.1)
  re-classifies nodes against labeled-centroid bank rows; and
  ``graph_agreement`` (§4.2.2) votes labels for unlabeled nodes from
  their nearest bank neighbors. Each write is tagged with the checkpoint
  step the maker loaded — ``ckpt_version_lag`` measures per-maker data
  freshness against the live trainer clock.
- Knowledge Bank: ONE request-coalescing ``KnowledgeBankServer``.

The sync diff path runs the SAME maker math inline (through the ``KBOps``
facade, like examples/curriculum_label_mining.py) on the async run's
final checkpoint, so the two label curricula can be compared directly:
what asynchrony costs (stale votes) and buys (zero trainer-path work).

Run:  PYTHONPATH=src python examples/async_semisup.py [--steps N]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (feature_store_create, format_maker_stats,
                        fs_update_labels, graph_agreement_labels, kb_create,
                        make_embed_fn, make_kb_ops, run_async_training)
from repro.data import SyntheticGraphCorpus
from repro.models import build_model
from repro.sharding.partition import DistContext


def label_report(tag, labels, true_labels):
    known = labels >= 0
    acc = (labels[known] == true_labels[known]).mean() if known.any() else 0.0
    print(f"{tag}: {known.sum()}/{labels.size} nodes labeled, "
          f"acc {acc:.3f}")
    return acc


def sync_label_passes(params, model, corpus, n_classes, dist):
    """The diff baseline: the same mining + agreement math, run inline
    through the in-graph KBOps facade on one final checkpoint."""
    cfg = model.cfg
    ops = make_kb_ops(dist)
    embed = jax.jit(make_embed_fn(model, dist))
    kb = kb_create(corpus.num_nodes, cfg.d_model)
    for lo in range(0, corpus.num_nodes, 128):
        ids = np.arange(lo, min(lo + 128, corpus.num_nodes))
        emb = embed(params, jnp.asarray(corpus.node_tokens(ids)[:, :-1]))
        kb = ops.update(kb, jnp.asarray(ids), emb)
    emb_all = np.asarray(kb.table)

    fs = feature_store_create(corpus.num_nodes, 8)
    lab = corpus.labeled_ids
    noisy = corpus.noisy_labels[lab]
    fs = fs_update_labels(fs, jnp.asarray(lab), jnp.asarray(noisy),
                          jnp.full(len(lab), 0.5))
    # inline label mining (§4.2.1): labeled-centroid read-out, conf-gated
    cent = np.stack([emb_all[lab][noisy == c].mean(0)
                     if (noisy == c).any() else np.zeros(cfg.d_model)
                     for c in range(n_classes)])
    conf = jax.nn.softmax(jnp.asarray(emb_all[lab] @ cent.T * 20.0), -1)
    fs = fs_update_labels(fs, jnp.asarray(lab),
                          jnp.asarray(np.asarray(conf.argmax(-1)),
                                      dtype=jnp.int32),
                          jnp.asarray(np.asarray(conf.max(-1))))
    # inline graph agreement (§4.2.2) for the unlabeled rest
    unlabeled = np.setdiff1d(np.arange(corpus.num_nodes), lab)
    pred, vconf = graph_agreement_labels(
        kb, fs, jnp.asarray(emb_all[unlabeled]), jnp.asarray(unlabeled),
        k=8, num_classes=n_classes, kb_ops=ops)
    fs = fs_update_labels(fs, jnp.asarray(unlabeled), pred, vconf)
    return np.asarray(fs.labels)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--nodes", type=int, default=512)
    args = ap.parse_args()

    n_classes = 4
    corpus = SyntheticGraphCorpus(num_nodes=args.nodes,
                                  num_clusters=n_classes,
                                  neighbors_per_node=4, labeled_frac=0.3,
                                  label_noise=0.4, seed=0)
    cfg = get_config("minitron-4b").reduced().replace(num_layers=2)
    model = build_model(cfg)
    dist = DistContext()

    print(f"=== async semi-supervised CARLS: {args.nodes} nodes, "
          f"{n_classes} classes, 30% labeled at 40% noise ===")
    res = run_async_training(
        model, corpus, steps=args.steps, batch_size=16,
        makers=["embedding_refresh", "label_mining", "graph_agreement"],
        maker_batch=64, ckpt_period=5, lr=3e-3, trainer_push=True, seed=0)
    print(f"loss {res.losses[0]:.3f} -> {np.mean(res.losses[-5:]):.3f}, "
          f"graph-reg {res.reg_losses[0]:.4f} -> "
          f"{np.mean(res.reg_losses[-5:]):.4f} "
          f"(regularizer fed from maker-refreshed bank rows)")
    for line in format_maker_stats(res.server.maker_stats):
        print(line)

    fs = res.runtime.feature_store
    acc_async = label_report("async curriculum", fs.labels(),
                             corpus.true_labels)
    labels_sync = sync_label_passes(res.final_params, model, corpus,
                                    n_classes, dist)
    acc_sync = label_report("sync  curriculum (same ckpt, inline passes)",
                            labels_sync, corpus.true_labels)
    lab = corpus.labeled_ids
    base = (corpus.noisy_labels[lab] == corpus.true_labels[lab]).mean()
    print(f"seed (noisy) label acc: {base:.3f}; "
          f"async-vs-sync acc gap: {acc_async - acc_sync:+.3f} "
          f"(asynchrony trades vote freshness for zero trainer-path cost)")


if __name__ == "__main__":
    main()
