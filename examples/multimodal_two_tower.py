"""Multimodal learning with CARLS (paper §4.3, Fig. 5): an image-text-style
two-tower model trained with a contrastive loss where the negative pool is
served by the Knowledge Bank and refreshed maker-style, instead of being
limited to the in-batch negatives.

Run:  PYTHONPATH=src python examples/multimodal_two_tower.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import kb_create, kb_lookup, kb_update
from repro.data import PairedCorpus
from repro.models import build_model
from repro.models.losses import contrastive_loss, masked_mean_pool
from repro.optim import AdamW, constant_lr
from repro.sharding.partition import DistContext

DIST = DistContext()


def embed(model, params, toks):
    h, _, _, _ = model.hidden(params, toks, {}, DIST)
    return masked_mean_pool(h, jnp.ones(toks.shape, jnp.float32))


def recall_at_1(ma, mb, params, corpus, n=128):
    ev = corpus.batch(np.random.default_rng(99), n)
    ea = embed(ma, params["a"], jnp.asarray(ev["tokens_a"]))
    eb = embed(mb, params["b"], jnp.asarray(ev["tokens_b"]))
    sim = np.asarray(ea @ eb.T)
    return float((sim.argmax(1) == np.arange(n)).mean())


def train(n_negatives, steps=60, batch=16, seed=0):
    cfg = get_config("internvl2-2b").reduced().replace(num_layers=2,
                                                       frontend="none")
    corpus = PairedCorpus(num_pairs=1024, vocab_size=cfg.vocab_size,
                          num_concepts=32, seed=0)
    ma, mb = build_model(cfg), build_model(cfg)
    ka, kb_key = jax.random.split(jax.random.key(seed))
    params = {"a": ma.init(ka), "b": mb.init(kb_key)}
    opt = AdamW(lr=constant_lr(2e-3), weight_decay=0.0)
    st = opt.init(params)
    bank = kb_create(corpus.num_pairs, cfg.d_model)

    @jax.jit
    def step(params, st, bank, ta, tb, neg_ids):
        negs, bank = kb_lookup(bank, neg_ids, apply_pending=False)

        def loss_fn(p):
            ea = embed(ma, p["a"], ta)
            eb = embed(mb, p["b"], tb)
            extra = negs if n_negatives else None
            return contrastive_loss(ea, eb, extra_negatives=extra), eb

        (l, eb), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, st, _ = opt.update(g, st, params)
        return params, st, bank, l, eb

    rng = np.random.default_rng(seed)
    for s in range(steps):
        b = corpus.batch(rng, batch)
        neg_ids = jnp.asarray(
            rng.integers(0, corpus.num_pairs, (max(n_negatives, 1),)))
        params, st, bank, l, eb = step(params, st, bank,
                                       jnp.asarray(b["tokens_a"]),
                                       jnp.asarray(b["tokens_b"]), neg_ids)
        # knowledge-maker role: keep the bank's tower-b embeddings fresh
        bank = kb_update(bank, jnp.asarray(b["ids"]), eb)
    return recall_at_1(ma, mb, params, corpus), float(l)


def main():
    print("=== two-tower contrastive: scaling negatives via the KB ===")
    for n_neg in (0, 64, 256):
        r1, loss = train(n_neg)
        print(f"negatives={n_neg:4d}: recall@1={r1:.3f} final_loss={loss:.3f}"
              f"  (extra negatives cost one KB lookup, not {n_neg} encoder"
              " passes)")


if __name__ == "__main__":
    main()
