"""End-to-end driver: train a ~100M-parameter graph-regularized LM with the
full CARLS stack (in-graph KB + synchronous maker refresh + checkpointing).

The default config below is the ~100M model (documented target: a few
hundred steps). On this CPU-only container that is hours of compute, so
--preset tiny (default when run without args under pytest/bench budgets)
trains a ~6M model for 60 steps; --preset full runs the 100M config.

  PYTHONPATH=src python examples/train_lm.py --preset tiny
  PYTHONPATH=src python examples/train_lm.py --preset full --steps 300

--preset async runs the same tiny model through the asynchronous topology
(launch/train.py --makers): trainer + label_mining + graph_agreement maker
threads against one coalescing KB server, per-maker counters printed at
the end. Every preset's KB traffic goes through the KBOps engine facade.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main

PRESETS = {
    # ~100M params: 12L x d512 (llama-style, yi family reduced upward)
    "full": ["--arch", "yi-6b", "--layers", "12", "--d-model", "512",
             "--seq", "256", "--batch", "8", "--steps", "300",
             "--nodes", "4096", "--ckpt-every", "100"],
    "small": ["--arch", "yi-6b", "--layers", "4", "--d-model", "256",
              "--seq", "128", "--batch", "8", "--steps", "100",
              "--nodes", "2048"],
    "tiny": ["--arch", "yi-6b", "--layers", "2", "--seq", "64",
             "--batch", "8", "--steps", "60", "--nodes", "1024"],
    # the async CARLS topology: trainer + maker threads on one KB server
    "async": ["--arch", "yi-6b", "--layers", "2", "--seq", "64",
              "--batch", "8", "--steps", "60", "--nodes", "1024",
              "--makers", "label_mining,graph_agreement",
              "--ckpt-period", "5"],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=0)
    args, rest = ap.parse_known_args()
    argv = PRESETS[args.preset] + rest
    if args.steps:
        argv += ["--steps", str(args.steps)]
    train_main(argv)


if __name__ == "__main__":
    main()
