"""Curriculum learning with CARLS (paper §4.2): online label mining +
graph agreement.

A corpus with 40% corrupted labels and only 30% of nodes labeled. Knowledge
makers (1) mine labels by re-classifying nodes against labeled-centroid
embeddings with confidence gating, and (2) infer labels for unlabeled nodes
via graph agreement (kNN vote over the KB's embedding space). The feature
store keeps the best-confidence label per node — the training curriculum
hardens as labels improve.

Run:  PYTHONPATH=src python examples/curriculum_label_mining.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (feature_store_create, fs_update_labels,
                        graph_agreement_labels, kb_create, make_embed_fn,
                        make_kb_ops, run_async_training)
from repro.data import SyntheticGraphCorpus
from repro.models import build_model
from repro.sharding.partition import DistContext


def main():
    n_nodes, n_classes = 1024, 4
    corpus = SyntheticGraphCorpus(num_nodes=n_nodes, num_clusters=n_classes,
                                  labeled_frac=0.3, label_noise=0.4, seed=0)
    cfg = get_config("minitron-4b").reduced().replace(num_layers=2)
    model = build_model(cfg)
    dist = DistContext()
    # makers operate on the LATEST TRAINER CHECKPOINT (§3.1) — train briefly
    # so the embedding space carries the model's learned structure
    print("training 50 steps so makers have a checkpoint to load...")
    res = run_async_training(model, corpus, steps=50, batch_size=16,
                             use_makers=False, reg_weight=0.0, lr=3e-3)
    params = res.final_params
    embed = jax.jit(make_embed_fn(model, dist))
    # all bank traffic below goes through the KBOps facade — the backend
    # (dense here; sharded on a mesh) is picked once, not per call site
    ops = make_kb_ops(dist)

    # --- knowledge maker pass 1: embed every node into the bank ----------
    kb = kb_create(n_nodes, cfg.d_model)
    for lo in range(0, n_nodes, 128):
        ids = np.arange(lo, min(lo + 128, n_nodes))
        emb = embed(params, jnp.asarray(corpus.node_tokens(ids)[:, :-1]))
        kb = ops.update(kb, jnp.asarray(ids), emb)

    fs = feature_store_create(n_nodes, 8)
    lab = corpus.labeled_ids
    noisy = corpus.noisy_labels[lab]
    fs = fs_update_labels(fs, jnp.asarray(lab), jnp.asarray(noisy),
                          jnp.full(len(lab), 0.5))
    base_acc = (noisy == corpus.true_labels[lab]).mean()
    print(f"labeled nodes: {len(lab)}/{n_nodes}, initial label acc "
          f"(noisy): {base_acc:.3f}")

    # --- maker pass 2: online label mining (§4.2.1) -----------------------
    emb_all = np.asarray(kb.table)
    cent = np.stack([emb_all[lab][noisy == c].mean(0)
                     if (noisy == c).any() else np.zeros(cfg.d_model)
                     for c in range(n_classes)])
    logits = emb_all[lab] @ cent.T
    conf = jax.nn.softmax(jnp.asarray(logits * 20.0), -1)
    mined_conf = np.asarray(conf.max(-1))
    mined = np.asarray(conf.argmax(-1)).astype(np.int32)
    fs = fs_update_labels(fs, jnp.asarray(lab), jnp.asarray(mined),
                          jnp.asarray(mined_conf))
    cur = np.asarray(fs.labels[lab])
    print(f"after label mining: label acc "
          f"{(cur == corpus.true_labels[lab]).mean():.3f} "
          f"(confidence-gated, only higher-confidence labels replaced)")

    # --- maker pass 3: graph agreement for unlabeled nodes (§4.2.2) ------
    unlabeled = np.setdiff1d(np.arange(n_nodes), lab)
    pred, conf = graph_agreement_labels(
        kb, fs, jnp.asarray(emb_all[unlabeled]), jnp.asarray(unlabeled),
        k=8, num_classes=n_classes, kb_ops=ops)
    acc_unl = (np.asarray(pred) == corpus.true_labels[unlabeled]).mean()
    print(f"graph-agreement labels for {len(unlabeled)} unlabeled nodes: "
          f"acc {acc_unl:.3f}")
    fs = fs_update_labels(fs, jnp.asarray(unlabeled), pred, conf)
    total = np.asarray(fs.labels)
    known = total >= 0
    print(f"curriculum state: {known.sum()}/{n_nodes} nodes labeled, "
          f"overall acc {(total[known] == corpus.true_labels[known]).mean():.3f}")


if __name__ == "__main__":
    main()
