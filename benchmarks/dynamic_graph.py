"""Paper "Flexibility" claim (§1, §3.1): the graph can be *constructed and
updated dynamically from the current model state* rather than fixed up
front. Measures (a) the cost of a graph-builder maker pass (NN search over
the bank + feature-store write) and (b) the quality of discovered neighbors
(same-latent-cluster rate) vs the static random-graph baseline, as the
bank embeddings improve."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (feature_store_create, kb_create, kb_update,
                        make_embed_fn, make_graph_builder)
from repro.data import SyntheticGraphCorpus
from repro.models import build_model
from repro.sharding.partition import DistContext

DIST = DistContext()


def run(quick: bool = False) -> List[Dict]:
    n = 512 if quick else 2048
    corpus = SyntheticGraphCorpus(num_nodes=n, num_clusters=8, seed=0)
    cfg = get_config("yi-6b").reduced().replace(num_layers=2)
    model = build_model(cfg)
    embed = jax.jit(make_embed_fn(model, DIST))
    params = model.init(jax.random.key(0))
    ids = np.arange(n)
    emb = np.asarray(embed(params, jnp.asarray(corpus.node_tokens(ids)[:, :-1])))
    kb = kb_create(n, cfg.d_model)
    kb = kb_update(kb, jnp.asarray(ids), jnp.asarray(emb))
    fs = feature_store_create(n, 8)
    builder = jax.jit(make_graph_builder(DIST, k=8))
    q = jnp.asarray(ids[:256])
    fs = builder(kb, fs, q)              # compile
    t0 = time.perf_counter()
    fs = builder(kb, fs, q)
    jax.block_until_ready(fs.nbr_ids)
    dt = time.perf_counter() - t0
    nbrs = np.asarray(fs.nbr_ids[:256])
    same = (corpus.clusters[nbrs] == corpus.clusters[:256][:, None]).mean()
    rng = np.random.default_rng(0)
    rand_same = (corpus.clusters[rng.integers(0, n, nbrs.shape)] ==
                 corpus.clusters[:256][:, None]).mean()
    return [{
        "name": f"dynamic_graph/build256_of_{n}",
        "us_per_call": dt * 1e6,
        "derived": (f"same_cluster_rate={same:.3f} random_baseline="
                    f"{rand_same:.3f}")}]
