"""Paper §3.2: the lazy update scheme (cache -> average + outlier detection
on next lookup) vs (a) naive immediate SGD scatter (last-writer-wins bias
under conflicts) and (b) no outlier rejection, when multiple trainers push
gradients for the SAME rows and one trainer occasionally emits a corrupted
(outlier) gradient. Metric: distance of the resulting row to the oracle row
(updated with the mean of the CLEAN gradients).

Runs through the KB engine (``repro.core.kb_engine``) — the same jitted
bucketed ops the coalescing server executes — so the timing column reflects
the serving path, not raw functional calls. The immediate-scatter ablation
is the engine's ``lazy_update=False`` mode."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import KBEngine


def run(quick: bool = False) -> List[Dict]:
    N, D = 128, 32
    n_trainers = 4
    n_rounds = 10 if quick else 30
    rng = np.random.default_rng(0)
    rows_out = []
    for mode in ("lazy+outlier", "lazy-no-outlier", "naive-scatter"):
        lazy = mode.startswith("lazy")
        entry_zmax = 2.0 if mode == "lazy+outlier" else 0.0
        eng = KBEngine(N, D, lazy_lr=0.1, zmax=1e9, entry_zmax=entry_zmax,
                       lazy_update=lazy)
        base = eng.table_snapshot().copy()
        oracle = base.copy()
        eng.warmup(8)           # compile the jit buckets outside the timing
        t0 = time.perf_counter()
        err_acc = []
        for r in range(n_rounds):
            ids = rng.integers(0, N, (8,)).astype(np.int32)
            clean = rng.normal(size=(n_trainers, 8, D)).astype(np.float32)
            grads = clean.copy()
            grads[r % n_trainers] *= 100.0          # one corrupted trainer
            for t in range(n_trainers):
                eng.lazy_grad(ids, grads[t])
            if lazy:
                eng.lookup(ids)                     # apply cached average
            # oracle: mean of clean gradients, one update per round
            for j, i in enumerate(ids):
                oracle[i] -= 0.1 * clean[:, j].mean(0)
            err = np.linalg.norm(eng.table_snapshot() - oracle,
                                 axis=-1).mean()
            err_acc.append(err)
        dt = (time.perf_counter() - t0) / n_rounds
        rows_out.append({
            "name": f"lazy_update/{mode}",
            "us_per_call": dt * 1e6,
            "derived": f"mean_err_vs_clean_oracle={np.mean(err_acc):.4f}"})
    return rows_out
