"""Benchmark harness — one module per paper table/claim. Prints
``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only SUITE]

Suites:
  neighbor_scaling : §1/§4.1 — CARLS step ~flat in K, inline baseline linear
  staleness        : §1     — freshness impact controllable
  lazy_update      : §3.2   — lazy average + outlier rejection stability
  two_tower        : §4.3   — KB-scaled negative pools
  nn_search_bench  : §3.2   — NN lookup: exact/IVF/sharded-IVF + recall
  dynamic_graph    : §4.1   — graph growth under async maker updates
  kb_serving       : §3.2   — request-coalescing server vs per-call lock

``--quick`` shrinks every suite (nn_search_bench drops to N<=16384 but
still exercises the sharded-IVF row — the CI smoke path).
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

SUITES = ["neighbor_scaling", "staleness", "lazy_update", "two_tower",
          "nn_search_bench", "dynamic_graph", "kb_serving"]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    suites = [args.only] if args.only else SUITES
    print("name,us_per_call,derived")
    failed = 0
    for s in suites:
        try:
            mod = importlib.import_module(f"benchmarks.{s}")
            for row in mod.run(quick=args.quick):
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception:
            failed += 1
            print(f"{s},ERROR,\"{traceback.format_exc(limit=2)}\"",
                  file=sys.stderr, flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
