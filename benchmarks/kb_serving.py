"""KB serving throughput: request coalescing vs per-call locked dispatch.

The paper's bank serves many trainers and makers concurrently (§3.2,
Fig. 1). The seed reproduction executed one locked eager device round-trip
per caller; the engine-backed server instead coalesces concurrent requests
into one jitted batched op per queue drain. Three modes, 8 concurrent
lookup clients each:

- eager-locked : the seed ``KnowledgeBankServer`` behavior — per-call lock
                 around the unjitted functional ops (one eager device
                 round-trip per caller).
- jit-locked   : per-call lock around the engine's jitted bucketed ops
                 (``coalesce=False``) — dispatch amortization only.
- coalescing   : the dispatcher drains concurrent requests into one
                 batched op (``coalesce=True``).
- socket-loopback : the same coalescing server behind the TCP wire
                 protocol (``repro.core.kb_transport``) on 127.0.0.1 —
                 the 8 clients share one pipelined ``RemoteKnowledgeBank``
                 connection, so this row IS the transport overhead
                 (framing + loopback + codec) over the in-proc
                 coalescing row. Tracked so the cross-process seam
                 (ISSUE 5) can never silently regress serving.

Acceptance (ISSUE 1): coalescing >= 2x eager-locked lookup throughput at 8
clients. Buckets are pre-compiled via ``server.warmup`` so the numbers are
steady-state serving, not jit compiles.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (KBTransportServer, KnowledgeBankServer,
                        RemoteKnowledgeBank, knowledge_bank as kbm)

N, D = 4096, 64
CLIENTS = 8
BATCH = 32


class _EagerLockedServer:
    """The seed server's execution model: per-call lock, eager ops."""

    def __init__(self, num_entries: int, dim: int):
        self._kb = kbm.kb_create(num_entries, dim)
        self._lock = threading.Lock()

    def update(self, ids, values):
        with self._lock:
            self._kb = kbm.kb_update(self._kb, jnp.asarray(ids),
                                     jnp.asarray(values))

    def lookup(self, ids):
        with self._lock:
            vals, self._kb = kbm.kb_lookup(self._kb, jnp.asarray(ids))
            return np.asarray(vals)

    def close(self):
        pass


def _drive(server, calls_per_client: int) -> float:
    """8 concurrent lookup clients; returns lookups/second."""
    def client(t):
        rng = np.random.default_rng(100 + t)
        for _ in range(calls_per_client):
            server.lookup(rng.integers(0, N, (BATCH,)))

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(CLIENTS)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return CLIENTS * calls_per_client / (time.perf_counter() - t0)


def run(quick: bool = False) -> List[Dict]:
    calls = 30 if quick else 120
    table = np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)
    rows, thru = [], {}
    for mode in ("eager-locked", "jit-locked", "coalescing",
                 "socket-loopback"):
        transport = remote = None
        if mode == "eager-locked":
            server = _EagerLockedServer(N, D)
            server.update(np.arange(N), table)
            server.lookup(np.arange(BATCH))            # one-time tracing
        else:
            server = KnowledgeBankServer(N, D,
                                         coalesce=(mode != "jit-locked"))
            server.update(np.arange(N), table)
            server.warmup(BATCH * CLIENTS)
        target = server
        if mode == "socket-loopback":
            transport = KBTransportServer(server)
            remote = RemoteKnowledgeBank("127.0.0.1", transport.port,
                                         client_name="bench")
            remote.lookup(np.arange(BATCH))            # prime the wire
            target = remote
        thru[mode] = _drive(target, calls)
        extra = ""
        if mode == "coalescing":
            extra = (f" coalescing_factor={server.coalescing_factor:.1f}"
                     f" speedup_vs_eager="
                     f"{thru[mode] / thru['eager-locked']:.2f}x"
                     f" speedup_vs_jit="
                     f"{thru[mode] / thru['jit-locked']:.2f}x")
        if mode == "socket-loopback":
            # per-call wire cost = the whole row's delta vs in-proc
            overhead = 1e6 / thru[mode] - 1e6 / thru["coalescing"]
            extra = (f" coalescing_factor={server.coalescing_factor:.1f}"
                     f" wire_overhead_us={overhead:.0f}"
                     f" vs_inproc_coalescing="
                     f"{thru[mode] / thru['coalescing']:.2f}x")
            remote.close()
            transport.close()
        server.close()
        rows.append({
            "name": f"kb_serving/{mode}/clients={CLIENTS}",
            "us_per_call": 1e6 / thru[mode],
            "derived": f"lookups_per_s={thru[mode]:.0f}{extra}"})
    return rows
