"""KB serving throughput: request coalescing vs per-call locked dispatch.

The paper's bank serves many trainers and makers concurrently (§3.2,
Fig. 1). The seed reproduction executed one locked eager device round-trip
per caller; the engine-backed server instead coalesces concurrent requests
into one jitted batched op per queue drain. Three modes, 8 concurrent
lookup clients each:

- eager-locked : the seed ``KnowledgeBankServer`` behavior — per-call lock
                 around the unjitted functional ops (one eager device
                 round-trip per caller).
- jit-locked   : per-call lock around the engine's jitted bucketed ops
                 (``coalesce=False``) — dispatch amortization only.
- coalescing   : the dispatcher drains concurrent requests into one
                 batched op (``coalesce=True``).
- socket-loopback : the same coalescing server behind the TCP wire
                 protocol (``repro.core.kb_transport``) on 127.0.0.1 —
                 the 8 clients share one pipelined ``RemoteKnowledgeBank``
                 connection, so this row IS the transport overhead
                 (framing + loopback + codec) over the in-proc
                 coalescing row. Tracked so the cross-process seam
                 (ISSUE 5) can never silently regress serving.

Acceptance (ISSUE 1): coalescing >= 2x eager-locked lookup throughput at 8
clients. Buckets are pre-compiled via ``server.warmup`` so the numbers are
steady-state serving, not jit compiles.

Scale-out rows (ISSUE 6): aggregate lookup throughput and router nn_search
p50 at 1/2/4 in-process partitions, plus the dispatcher's cross-op
reordering on vs off. On this one-core container partitioning cannot buy
thread parallelism; what it buys is the per-dispatch functional-update
cost — every un-donated jitted drain copies the whole table+grad arrays,
O(rows), with a cache cliff above ~32k rows — so a partition's drain pays
O(N/P) where the monolith pays O(N). The drive therefore saturates each
server's queue via pipelined ``enqueue_op`` ingestion (every drain hits
the ``max_coalesce`` cap in both configs) with partition-local request
batches, i.e. the router's single-partition fast path; requests that
straddle partitions split into sub-requests and keep the aggregate the
same. Acceptance: >= 1.6x aggregate lookup QPS at 2 partitions vs 1, and
reorder-on >= 1.2x over FIFO on interleaved lookup/update streams with
bit-identical results + final table. Everything lands in
``BENCH_kb_serving.json`` (validated by ``tools/check_docs.py``).

Mixed rows (ISSUE 10): protocol v4's multiplexed wire. One connection,
8 threads hammering bulk ``nn_search`` while a 9th times point lookups;
``kb_serving/mixed/fifo`` delivers responses in request-arrival order (the
v3 contract) and ``kb_serving/mixed/v4-lanes`` is the multiplexed wire
(out-of-order completion + weighted priority lanes). Acceptance: lanes
cuts lookup p99 >= 3x with bit-identical results.

Storage rows (ISSUE 7): int8 rows vs fp32 (memory per row, lookup
throughput, quantized-IVF recall@10) and a cold-tier run where the bank
is 4x its resident device tier and must fault rows in on demand.
Acceptance: >= 3.5x bytes_per_row reduction, int8 lookups within 1.3x of
fp32, recall@10 >= 0.95, and the oversubscribed bank serves bit-exact
rows.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (InProcessTransport, KBEngine, KBRouter,
                        KBTransportServer, KnowledgeBankServer,
                        PartitionMap, RemoteKnowledgeBank,
                        knowledge_bank as kbm)
from repro.core.ann_index import clustered_bank

N, D = 4096, 64
CLIENTS = 8
BATCH = 32

# scale-out drive: table big enough that the O(rows) per-dispatch copy is
# past the cache cliff (the regime the router exists for) and a drain cap
# small enough that both configs saturate it
SCALE_N, SCALE_D = 131072, 64
SCALE_CAP = 8          # max_coalesce for every server in the comparison
SCALE_B = 16           # ids per lookup request
SCALE_PARTS = (1, 2, 4)


class _EagerLockedServer:
    """The seed server's execution model: per-call lock, eager ops."""

    def __init__(self, num_entries: int, dim: int):
        self._kb = kbm.kb_create(num_entries, dim)
        self._lock = threading.Lock()

    def update(self, ids, values):
        with self._lock:
            self._kb = kbm.kb_update(self._kb, jnp.asarray(ids),
                                     jnp.asarray(values))

    def lookup(self, ids):
        with self._lock:
            vals, self._kb = kbm.kb_lookup(self._kb, jnp.asarray(ids))
            return np.asarray(vals)

    def close(self):
        pass


def _drive(server, calls_per_client: int) -> float:
    """8 concurrent lookup clients; returns lookups/second."""
    def client(t):
        rng = np.random.default_rng(100 + t)
        for _ in range(calls_per_client):
            server.lookup(rng.integers(0, N, (BATCH,)))

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(CLIENTS)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return CLIENTS * calls_per_client / (time.perf_counter() - t0)


def _fill(server, num_rows: int, dim: int, seed: int) -> None:
    vals = np.random.default_rng(seed).normal(
        size=(num_rows, dim)).astype(np.float32)
    server.update(np.arange(num_rows), vals)


def _partition_fleet(scale_n: int, parts: int, max_coalesce: int,
                     reorder: bool = False):
    """P servers sized by the router's PartitionMap, each filled from the
    SAME global table (row g of the global table lives at the local rank
    the router would send it to)."""
    pmap = PartitionMap(scale_n, parts)
    table = np.random.default_rng(7).normal(
        size=(scale_n, SCALE_D)).astype(np.float32)
    servers = []
    for p in range(parts):
        s = KnowledgeBankServer(int(pmap.counts[p]), SCALE_D,
                                max_coalesce=max_coalesce, reorder=reorder)
        s.update(np.arange(int(pmap.counts[p])), table[pmap.global_ids(p)])
        s.warmup(SCALE_B * max_coalesce)
        servers.append(s)
    return pmap, servers


def _saturated_lookup_qps(servers, pmap, m: int) -> float:
    """Pre-enqueue m partition-local lookup requests (round-robin across
    partitions, affine local ids) and wait for all — the pipelined
    ingestion path (``enqueue_op``, same as the wire reader), so every
    drain hits max_coalesce and the number is dispatch cost, not client
    turnaround. Returns served ids/second."""
    plan = []
    for j in range(m):
        p = j % len(servers)
        n_p = int(pmap.counts[p])
        start = (j * 97) % max(1, n_p - SCALE_B)
        plan.append((p, (np.arange(SCALE_B) + start) % n_p))
    t0 = time.perf_counter()
    pending = [servers[p].enqueue_op("lookup", ids=ids, shape=ids.shape)
               for p, ids in plan]
    for r in pending:
        r.wait()
    return m * SCALE_B / (time.perf_counter() - t0)


def _router_nn_p50_us(servers, pmap, calls: int) -> float:
    """Median per-call latency of a fanned-out router nn_search (k=10)."""
    router = KBRouter(
        [InProcessTransport(s, partition=f"{p}/{len(servers)}")
         for p, s in enumerate(servers)], pmap=pmap)
    q = np.random.default_rng(11).normal(size=(4, SCALE_D)) \
        .astype(np.float32)
    router.nn_search(q, k=10)                              # warm the merge
    lat = []
    for _ in range(calls):
        t0 = time.perf_counter()
        router.nn_search(q, k=10)
        lat.append(time.perf_counter() - t0)
    return float(np.median(lat) * 1e6)


def _reorder_trial(reorder: bool, m: int):
    """One server, interleaved lookup/update streams over DISJOINT id
    halves (lookups in [0, N/2), updates in [N/2, N)) pre-enqueued so
    drains see the alternation. FIFO forms m runs of 1; reorder=True
    hoists each op over the commuting other-op stream into ~2 runs per
    drain. Returns (elapsed_s, lookup_results, final_table, reorders)."""
    server = KnowledgeBankServer(SCALE_N, SCALE_D, max_coalesce=SCALE_CAP,
                                 reorder=reorder)
    _fill(server, SCALE_N, SCALE_D, seed=7)
    server.warmup(SCALE_B * SCALE_CAP)
    half = SCALE_N // 2
    rng = np.random.default_rng(13)
    look = [(np.arange(SCALE_B) + (j * 89) % (half - SCALE_B)) % half
            for j in range(m // 2)]
    # pairwise-DISJOINT update blocks: merged update runs concatenate into
    # one scatter, and duplicate ids across merged requests could resolve
    # in a different order than sequential FIFO application would
    upd = [half + j * SCALE_B + np.arange(SCALE_B)
           for j in range(m // 2)]
    assert (m // 2) * SCALE_B <= half
    upd_vals = [rng.normal(size=(SCALE_B, SCALE_D)).astype(np.float32)
                for _ in range(m // 2)]
    t0 = time.perf_counter()
    pending = []
    for j in range(m):
        if j % 2 == 0:
            pending.append(server.enqueue_op(
                "lookup", ids=look[j // 2], shape=look[j // 2].shape))
        else:
            pending.append(server.enqueue_op(
                "update", ids=upd[j // 2], payload=upd_vals[j // 2]))
    results = [r.wait() for r in pending]
    dt = time.perf_counter() - t0
    looks = [np.asarray(r) for r in results[0::2]]
    snap = np.asarray(server.table_snapshot())
    reorders = server.metrics["reorders"]
    server.close()
    return dt, looks, snap, reorders


def _run_scaleout(quick: bool, rows: List[Dict], raw: Dict) -> None:
    m = 48 if quick else 240
    nn_calls = 3 if quick else 11
    scaleout, base_qps = [], None
    for parts in SCALE_PARTS:
        pmap, servers = _partition_fleet(SCALE_N, parts, SCALE_CAP)
        qps = _saturated_lookup_qps(servers, pmap, m)
        nn_p50 = _router_nn_p50_us(servers, pmap, nn_calls)
        for s in servers:
            s.close()
        base_qps = base_qps or qps
        speedup = qps / base_qps
        scaleout.append({"partitions": parts, "lookups_per_s": qps,
                         "nn_p50_us": nn_p50,
                         "speedup_vs_1p": speedup})
        rows.append({
            "name": f"kb_serving/scaleout/p={parts}",
            "us_per_call": 1e6 * SCALE_B / qps,
            "derived": f"lookups_per_s={qps:.0f}"
                       f" speedup_vs_1p={speedup:.2f}x"
                       f" nn_p50_us={nn_p50:.0f}"})
    raw["scaleout"] = scaleout


def _run_reorder(quick: bool, rows: List[Dict], raw: Dict) -> None:
    m = 32 if quick else 96
    t_fifo, looks_f, snap_f, _ = _reorder_trial(False, m)
    t_re, looks_r, snap_r, reorders = _reorder_trial(True, m)
    identical = (all(np.array_equal(a, b)
                     for a, b in zip(looks_f, looks_r))
                 and np.array_equal(snap_f, snap_r))
    speedup = t_fifo / t_re
    raw["reorder"] = {"fifo_s": t_fifo, "reorder_s": t_re,
                      "speedup": speedup, "reorders": int(reorders),
                      "bit_identical": bool(identical)}
    for name, dt in (("reorder-off", t_fifo), ("reorder-on", t_re)):
        extra = ""
        if name == "reorder-on":
            extra = (f" speedup_vs_fifo={speedup:.2f}x"
                     f" reorders={reorders}"
                     f" bit_identical={identical}")
        rows.append({"name": f"kb_serving/{name}/interleaved",
                     "us_per_call": 1e6 * dt / m,
                     "derived": f"requests_per_s={m / dt:.0f}{extra}"})


def _run_storage(quick: bool, rows: List[Dict], raw: Dict) -> None:
    """int8 rows vs fp32 (ISSUE 7): memory per row, saturated lookup
    throughput, and quantized-IVF shortlist recall.

    Acceptance: >= 3.5x bytes_per_row reduction at int8 (D=64: 256 B vs
    64 + 8 B scale/offset), int8 lookup throughput within 1.3x of fp32,
    and recall@10 >= 0.95 for quantized IVF against exact fp32 search."""
    calls = 20 if quick else 80
    table = np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)
    stor: Dict[str, Dict] = {}
    for mode in ("fp32", "int8"):
        server = KnowledgeBankServer(N, D, storage=mode)
        server.update(np.arange(N), table)
        server.warmup(BATCH * CLIENTS)
        qps = _drive(server, calls)
        st = server.stats()["storage"]
        server.close()
        stor[mode] = {"bytes_per_row": int(st["bytes_per_row"]),
                      "bytes_resident": int(st["bytes_resident"]),
                      "lookups_per_s": qps}
    ratio = stor["fp32"]["bytes_per_row"] / stor["int8"]["bytes_per_row"]
    slowdown = (stor["fp32"]["lookups_per_s"]
                / stor["int8"]["lookups_per_s"])

    # recall: quantized IVF (shortlist scored via the int8 decomposition,
    # winners re-ranked against the fp32 masters) vs exact fp32 search
    n = 2048
    bank = np.asarray(clustered_bank(n, D, 32, seed=3))
    rng = np.random.default_rng(10)
    q = (bank[rng.integers(0, n, 32)]
         + 0.05 * rng.normal(size=(32, D))).astype(np.float32)
    e32 = KBEngine(n, D, backend="dense")
    e32.update(np.arange(n), bank)
    _, ref = e32.nn_search(q, 10, mode="exact")
    e8 = KBEngine(n, D, backend="dense", storage="int8", master_rows=n,
                  search_mode="ivf", ann_nlist=32, ann_nprobe=8)
    e8.update(np.arange(n), bank)
    e8.rebuild_ann_index()
    _, ids = e8.nn_search(q, 10, mode="ivf")
    hits = sum(len(set(ids[b].tolist()) & set(ref[b].tolist()))
               for b in range(len(ref)))
    recall = hits / (len(ref) * 10)

    raw["storage"] = {**stor, "bytes_per_row_ratio": ratio,
                      "lookup_slowdown_int8": slowdown,
                      "ivf_recall_at_10": recall}
    for mode in ("fp32", "int8"):
        extra = ""
        if mode == "int8":
            extra = (f" bytes_per_row_ratio={ratio:.2f}x"
                     f" lookup_slowdown={slowdown:.2f}x"
                     f" ivf_recall_at_10={recall:.3f}")
        rows.append({
            "name": f"kb_serving/storage/{mode}",
            "us_per_call": 1e6 / stor[mode]["lookups_per_s"],
            "derived": f"bytes_per_row={stor[mode]['bytes_per_row']}"
                       f" lookups_per_s="
                       f"{stor[mode]['lookups_per_s']:.0f}{extra}"})


def _run_cold_tier(quick: bool, rows: List[Dict], raw: Dict) -> None:
    """Tiered residency (ISSUE 7): a bank 4x larger than its resident
    device tier serves lookups correctly, faulting cold rows in on
    demand. Acceptance: every served row matches the fill table."""
    n_total, resident = 8192, 2048
    verify_batches = 8 if quick else 32
    table = np.random.default_rng(5).normal(
        size=(n_total, D)).astype(np.float32)
    server = KnowledgeBankServer(n_total, D, resident_rows=resident,
                                 cold_after_rows=resident // 2,
                                 coalesce=False)
    for lo in range(0, n_total, resident // 2):
        hi = min(lo + resident // 2, n_total)
        server.update(np.arange(lo, hi), table[lo:hi])
    rng = np.random.default_rng(6)
    correct = True
    t0 = time.perf_counter()
    for _ in range(verify_batches):
        ids = rng.integers(0, n_total, (BATCH,))
        got = server.lookup(ids)
        correct = correct and np.array_equal(got, table[ids])
    dt = time.perf_counter() - t0
    st = server.stats()["storage"]
    server.close()
    raw["cold_tier"] = {
        "total_rows": n_total, "resident_rows": resident,
        "oversubscription": n_total / resident,
        "bytes_resident": int(st["bytes_resident"]),
        "cold_rows": int(st["cold_rows"]),
        "tier_faults": int(st["tier_faults"]),
        "tier_spills": int(st["tier_spills"]),
        "lookups_correct": bool(correct)}
    rows.append({
        "name": f"kb_serving/cold-tier/{n_total // resident}x",
        "us_per_call": 1e6 * dt / verify_batches,
        "derived": f"resident={resident}/{n_total}"
                   f" cold_rows={st['cold_rows']}"
                   f" tier_faults={st['tier_faults']}"
                   f" tier_spills={st['tier_spills']}"
                   f" lookups_correct={correct}"})


def _mixed_trial(scheduler: str, hogs: int, hog_calls: int,
                 look_calls: int, table: np.ndarray):
    """One mixed-workload run: ``hogs`` threads hammering bulk nn_search
    while one thread times point lookups, ALL sharing one pipelined wire
    connection. Returns (p99_ms, p50_ms, lookup_results, nn_results) —
    the result arrays are compared across schedulers bit-for-bit."""
    server = KnowledgeBankServer(N, D)
    server.update(np.arange(N), table)
    server.warmup(BATCH * CLIENTS)
    transport = KBTransportServer(server, scheduler=scheduler)
    remote = RemoteKnowledgeBank("127.0.0.1", transport.port,
                                 client_name=f"bench-mixed-{scheduler}")
    remote.lookup(np.arange(BATCH))                        # prime the wire
    remote.nn_search(table[:64], 32)
    lat: List[float] = []
    looks: List[np.ndarray] = []
    nn_res: List[list] = [[] for _ in range(hogs)]
    done = threading.Event()

    def hog(h: int) -> None:
        rng = np.random.default_rng(50 + h)
        for _ in range(hog_calls):
            q = table[rng.integers(0, N, (64,))]
            nn_res[h].append(remote.nn_search(q, 32))
            # keep hogging until the timed thread finishes, but compare a
            # guaranteed-deterministic prefix across schedulers
            if done.is_set() and len(nn_res[h]) >= 3:
                break

    def looker() -> None:
        rng = np.random.default_rng(99)
        for _ in range(look_calls):
            ids = rng.integers(0, N, (BATCH,))
            t0 = time.perf_counter()
            looks.append(remote.lookup(ids))
            lat.append(time.perf_counter() - t0)
        done.set()

    threads = [threading.Thread(target=hog, args=(h,)) for h in range(hogs)]
    timed = threading.Thread(target=looker)
    for th in threads:
        th.start()
    time.sleep(0.05)                   # hogs in flight before timing opens
    timed.start()
    timed.join()
    for th in threads:
        th.join()
    remote.close()
    transport.close()
    server.close()
    arr = np.asarray(lat)
    return (float(np.percentile(arr, 99) * 1e3),
            float(np.median(arr) * 1e3), looks, nn_res)


def _run_mixed(quick: bool, rows: List[Dict], raw: Dict) -> None:
    """Protocol v4 mixed workload (ISSUE 10): point lookups racing
    concurrent bulk nn_search on ONE connection. scheduler="fifo" delivers
    responses in request-arrival order (the v3 contract — a completed
    lookup response queues behind every earlier-arrived in-flight search),
    scheduler="lanes" is the v4 multiplexed wire: out-of-order completion
    + weighted priority lanes let the point response overtake bulk.
    Acceptance: lanes cuts lookup p99 >= 3x with every result (lookups
    AND the common prefix of each hog's searches) bit-identical."""
    hogs = 8
    hog_calls, look_calls = (6, 120) if quick else (10, 400)
    table = np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)
    res = {s: _mixed_trial(s, hogs, hog_calls, look_calls, table)
           for s in ("fifo", "lanes")}
    nmin = [min(len(res["fifo"][3][h]), len(res["lanes"][3][h]))
            for h in range(hogs)]
    identical = (
        all(np.array_equal(a, b)
            for a, b in zip(res["fifo"][2], res["lanes"][2]))
        and all(np.array_equal(res["fifo"][3][h][i][j],
                               res["lanes"][3][h][i][j])
                for h in range(hogs) for i in range(nmin[h])
                for j in (0, 1)))
    improvement = res["fifo"][0] / res["lanes"][0]
    raw["mixed"] = {
        "hogs": hogs, "look_calls": look_calls,
        "lookup_p99_ms": {s: res[s][0] for s in ("fifo", "lanes")},
        "lookup_p50_ms": {s: res[s][1] for s in ("fifo", "lanes")},
        "p99_improvement": improvement,
        "bit_identical": bool(identical)}
    for sched, name in (("fifo", "fifo"), ("lanes", "v4-lanes")):
        extra = ""
        if sched == "lanes":
            extra = (f" p99_improvement={improvement:.2f}x"
                     f" bit_identical={identical}")
        rows.append({
            "name": f"kb_serving/mixed/{name}",
            "us_per_call": 1e3 * res[sched][0],
            "derived": f"lookup_p99_ms={res[sched][0]:.2f}"
                       f" lookup_p50_ms={res[sched][1]:.2f}"
                       f" nn_hogs={hogs}{extra}"})


def run(quick: bool = False) -> List[Dict]:
    calls = 30 if quick else 120
    table = np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)
    rows, thru = [], {}
    for mode in ("eager-locked", "jit-locked", "coalescing",
                 "socket-loopback"):
        transport = remote = None
        if mode == "eager-locked":
            server = _EagerLockedServer(N, D)
            server.update(np.arange(N), table)
            server.lookup(np.arange(BATCH))            # one-time tracing
        else:
            server = KnowledgeBankServer(N, D,
                                         coalesce=(mode != "jit-locked"))
            server.update(np.arange(N), table)
            server.warmup(BATCH * CLIENTS)
        target = server
        if mode == "socket-loopback":
            transport = KBTransportServer(server)
            remote = RemoteKnowledgeBank("127.0.0.1", transport.port,
                                         client_name="bench")
            remote.lookup(np.arange(BATCH))            # prime the wire
            target = remote
        thru[mode] = _drive(target, calls)
        extra = ""
        if mode == "coalescing":
            extra = (f" coalescing_factor={server.coalescing_factor:.1f}"
                     f" speedup_vs_eager="
                     f"{thru[mode] / thru['eager-locked']:.2f}x"
                     f" speedup_vs_jit="
                     f"{thru[mode] / thru['jit-locked']:.2f}x")
        if mode == "socket-loopback":
            # per-call wire cost = the whole row's delta vs in-proc
            overhead = 1e6 / thru[mode] - 1e6 / thru["coalescing"]
            extra = (f" coalescing_factor={server.coalescing_factor:.1f}"
                     f" wire_overhead_us={overhead:.0f}"
                     f" vs_inproc_coalescing="
                     f"{thru[mode] / thru['coalescing']:.2f}x")
            remote.close()
            transport.close()
        server.close()
        rows.append({
            "name": f"kb_serving/{mode}/clients={CLIENTS}",
            "us_per_call": 1e6 / thru[mode],
            "derived": f"lookups_per_s={thru[mode]:.0f}{extra}"})

    raw = {"config": {"N": N, "D": D, "clients": CLIENTS, "batch": BATCH,
                      "scale_N": SCALE_N, "scale_D": SCALE_D,
                      "scale_batch": SCALE_B, "max_coalesce": SCALE_CAP,
                      "quick": bool(quick)}}
    _run_storage(quick, rows, raw)
    _run_cold_tier(quick, rows, raw)
    _run_scaleout(quick, rows, raw)
    _run_reorder(quick, rows, raw)
    _run_mixed(quick, rows, raw)
    with open("BENCH_kb_serving.json", "w") as f:
        json.dump({"rows": rows, **raw}, f, indent=2)
    return rows
