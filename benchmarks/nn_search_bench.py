"""Paper §3.2: nearest-neighbor lookup over the bank, and the constant-
latency-via-sharding property: per-shard work is N/shards, and the
hierarchical merge is O(k * shards). Measures the Pallas kernel (interpret
mode — logic timing on CPU, not TPU perf) and the jnp reference."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _t(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out[0])
    return (time.perf_counter() - t0) / reps


def run(quick: bool = False) -> List[Dict]:
    D, B, k = 64, 16, 8
    sizes = [4096, 16384] if quick else [4096, 16384, 65536]
    rows = []
    q = jax.random.normal(jax.random.key(0), (B, D))
    for N in sizes:
        bank = jax.random.normal(jax.random.key(1), (N, D))
        t_ref = _t(jax.jit(lambda q, b: ref.nn_search_ref(q, b, k)), q, bank)
        rows.append({"name": f"nn_search/ref/N={N}",
                     "us_per_call": t_ref * 1e6,
                     "derived": f"qps={B/t_ref:.0f}"})
    # sharding claim: latency of one shard of N/16 + merge of 16*k candidates
    N = sizes[-1]
    bank = jax.random.normal(jax.random.key(1), (N, D))
    shard = bank[:N // 16]
    t_shard = _t(jax.jit(lambda q, b: ref.nn_search_ref(q, b, k)), q, shard)
    cand_s = jax.random.normal(jax.random.key(2), (B, 16 * k))
    t_merge = _t(jax.jit(lambda s: jax.lax.top_k(s, k)), cand_s)
    rows.append({"name": f"nn_search/sharded16/N={N}",
                 "us_per_call": (t_shard + t_merge) * 1e6,
                 "derived": f"vs_monolithic_x{(t_shard+t_merge)/_t(jax.jit(lambda q, b: ref.nn_search_ref(q, b, k)), q, bank):.2f}"})
    return rows
