"""Paper §3.2: nearest-neighbor lookup over the bank.

Four claims measured, on clustered (mixture-of-Gaussians) banks — the
distribution the IVF index is built for:

- exact paths: the jnp reference AND the blocked Pallas kernel (interpret
  mode — logic timing on CPU, not TPU perf);
- IVF vs exact (ISSUE 2 acceptance): the two-stage clustered search must
  beat the exact path >= 5x at N=65536 (B=16, k=8) while keeping
  recall@10 >= 0.95 — measured and reported in the ``derived`` column;
- constant-latency-via-sharding: per-shard work is N/shards, hierarchical
  merge is O(k * shards);
- sharded IVF vs sharded exact (ISSUE 3 acceptance): per-shard sub-indexes
  + hierarchical top-k merge must beat the sharded exact path >= 3x at
  N=65536 with recall@10 >= 0.95. Both sides run the meshless host
  simulations (``ivf_search_sharded_jnp`` vs a per-shard brute-force +
  merge), i.e. the same per-query arithmetic the shard_map ops execute —
  what a real mesh changes is that each shard's slice runs in parallel,
  which only widens the gap (IVF shrinks per-shard work N/S -> nprobe*cap);
- skew-proof stage 2 (ISSUE 9): on a skewed bank, the per-bucket chunk
  plan scores only each probed bucket's OCCUPIED chunks — the
  ``ivf_skew_*`` rows report the padded-vs-chunked work ratio and assert
  the results stay bit-identical;
- build early stop (ISSUE 9): ``kmeans`` now stops on centroid
  convergence; the ``ivf_build_fixed`` row re-times the old fixed-iteration
  build so the delta (and unchanged recall) is visible in CI diffs;
- autotuned operating point (ISSUE 9): ``tools/autotune_ann.py``'s sweep
  runs inline and the winning fp32 config lands as the ``autotuned`` row,
  which must meet recall@10 >= 0.95.

Emits ``BENCH_nn_search.json`` (cwd) with every row plus the raw
speedup/recall numbers so CI and later sessions can diff them.
"""
from __future__ import annotations

import functools
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ann_index import (QuantizedIVFIndex, build_ivf_index,
                                  build_sharded_ivf_index, clustered_bank)
from repro.core.knowledge_bank import quantize_rows
from repro.kernels import ops, ref
from repro.kernels.nn_search_ivf import (_chunk_rows, ivf_chunk_plan,
                                         ivf_probes, ivf_search_jnp,
                                         ivf_search_pallas,
                                         ivf_search_quantized_jnp,
                                         ivf_search_sharded_jnp)


def _t(fn, *args, reps=5):
    """Min-of-reps per-call latency (min is the noise-robust estimator on a
    shared/loaded host; means here swing 2x run-to-run)."""
    out = fn(*args)
    jax.block_until_ready(out[0])
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out[0])
        best = min(best, time.perf_counter() - t0)
    return best


def _t_pair(fn_a, args_a, fn_b, args_b, reps=12):
    """Interleaved min-of-reps for A-vs-B claims: a load spike on a shared
    host then penalizes both sides instead of whichever happened to be on
    the clock (back-to-back blocks here have produced 1.2x-12x swings in
    the same speedup)."""
    for fn, args in ((fn_a, args_a), (fn_b, args_b)):
        jax.block_until_ready(fn(*args)[0])
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args_a)[0])
        t1 = time.perf_counter()
        jax.block_until_ready(fn_b(*args_b)[0])
        best_a = min(best_a, t1 - t0)
        best_b = min(best_b, time.perf_counter() - t1)
    return best_a, best_b


def _recall(ids_approx, ids_exact):
    B, k = ids_exact.shape
    hits = sum(len(set(np.asarray(ids_approx)[b]) &
                   set(np.asarray(ids_exact)[b])) for b in range(B))
    return hits / (B * k)


@functools.partial(jax.jit, static_argnames=("k", "n_shards"))
def _sharded_exact(queries, bank, k: int, n_shards: int):
    """Host simulation of the exact sharded path: per-shard brute-force
    top-k, then the hierarchical candidate merge (same arithmetic as
    ``sharded_kb_nn_search``, minus the mesh)."""
    B = queries.shape[0]
    N = bank.shape[0]
    n_local = N // n_shards
    s = queries.astype(jnp.float32) @ bank.T.astype(jnp.float32)
    kk = min(k, n_local)
    ls, li = jax.lax.top_k(s.reshape(B, n_shards, n_local), kk)
    li = li + (jnp.arange(n_shards) * n_local)[None, :, None]
    gs, gi = jax.lax.top_k(ls.reshape(B, -1), k)
    return gs, jnp.take_along_axis(li.reshape(B, -1), gi, axis=1)


def run(quick: bool = False) -> List[Dict]:
    D, B, k = 64, 16, 8
    sizes = [4096, 16384] if quick else [4096, 16384, 65536]
    rows: List[Dict] = []
    raw = {"config": {"D": D, "B": B, "k": k, "recall_k": 10},
           "sizes": {}}
    for N in sizes:
        nlist = max(16, int(N ** 0.5))          # ~sqrt(N) partitions
        # nprobe tuned per size: clustered banks keep recall@10 = 1.0 down
        # to nprobe=2 (a query's neighbors live in its own cluster); the
        # measured recall in the derived column keeps this honest
        nprobe = 2 if N >= 65536 else 4
        bank = jnp.asarray(clustered_bank(N, D, nlist, noise=0.2, seed=1))
        # queries: perturbed bank rows (neighbor-discovery workload)
        qi = jax.random.randint(jax.random.key(2), (B,), 0, N)
        q = bank[qi] + 0.1 * jax.random.normal(jax.random.key(3), (B, D))

        # -- IVF index (built off the serving path) ------------------------
        t0 = time.perf_counter()
        idx = build_ivf_index(np.asarray(bank), nlist=nlist, iters=6)
        t_build = time.perf_counter() - t0

        # -- exact vs IVF, interleaved (the headline claim) ----------------
        exact_fn = jax.jit(lambda q, b: ref.nn_search_ref(q, b, k))
        ivf_args = (bank, idx.centroids, idx.packed_vecs, idx.packed_ids)
        jnp_fn = jax.jit(
            lambda t, c, pv, pi, q: ivf_search_jnp(t, c, pv, pi, q, k,
                                                   nprobe))
        t_ref, t_ivf = _t_pair(exact_fn, (q, bank), jnp_fn, (*ivf_args, q))
        rows.append({"name": f"nn_search/ref/N={N}",
                     "us_per_call": t_ref * 1e6,
                     "derived": f"qps={B/t_ref:.0f}"})
        t_pal = _t(lambda q, b: ops.nn_search_topk(q, b, k), q, bank)
        rows.append({"name": f"nn_search/pallas/N={N}",
                     "us_per_call": t_pal * 1e6,
                     "derived": f"interpret_vs_ref_x{t_pal/t_ref:.1f}"})
        rows.append({"name": f"nn_search/ivf_build/N={N}",
                     "us_per_call": t_build * 1e6,
                     "derived": f"nlist={idx.nlist},cap={idx.bucket_cap}"})
        # recall@10 against brute force (k=10 searches on both sides)
        _, i_ex10 = jax.jit(lambda q, b: ref.nn_search_ref(q, b, 10))(q, bank)
        _, i_iv10 = jax.jit(
            lambda t, c, pv, pi, q: ivf_search_jnp(t, c, pv, pi, q, 10,
                                                   nprobe))(*ivf_args, q)
        rec = _recall(i_iv10, np.asarray(i_ex10))
        speedup = t_ref / t_ivf
        rows.append({"name": f"nn_search/ivf/N={N}",
                     "us_per_call": t_ivf * 1e6,
                     "derived": f"recall@10={rec:.3f},"
                                f"vs_exact_x{speedup:.1f},nprobe={nprobe}"})
        t_ivf_pal = _t(lambda t, c, pv, pi, q: ops.nn_search_ivf(
            t, c, pv, pi, q, k, nprobe), *ivf_args, q)
        rows.append({"name": f"nn_search/ivf_pallas/N={N}",
                     "us_per_call": t_ivf_pal * 1e6,
                     "derived": f"interpret_vs_pallas_exact_"
                                f"x{t_ivf_pal/t_pal:.2f}"})
        # -- int8 quantized IVF (ISSUE 7): codes + per-row scale/offset,
        # fused dequant-by-decomposition inside the scoring loop
        qidx = QuantizedIVFIndex(idx)
        codes, qscl, qoff = quantize_rows(bank)
        q8_args = (codes, qscl, qoff, qidx.centroids, qidx.packed_codes,
                   qidx.packed_scale, qidx.packed_offset, qidx.packed_ids)
        q8_fn = jax.jit(functools.partial(ivf_search_quantized_jnp,
                                          k=10, nprobe=nprobe))
        t_q8 = _t(q8_fn, *q8_args, q)
        _, i_q810 = q8_fn(*q8_args, q)
        rec_q8 = _recall(np.asarray(i_q810), np.asarray(i_ex10))
        rows.append({"name": f"nn_search/ivf_int8/N={N}",
                     "us_per_call": t_q8 * 1e6,
                     "derived": f"recall@10={rec_q8:.3f},"
                                f"vs_fp32_ivf_x{t_q8/t_ivf:.2f}"})
        raw["sizes"][str(N)] = {
            "nlist": idx.nlist, "nprobe": nprobe,
            "bucket_cap": idx.bucket_cap,
            "us_exact_ref": t_ref * 1e6, "us_exact_pallas": t_pal * 1e6,
            "us_ivf_ref": t_ivf * 1e6, "us_ivf_pallas": t_ivf_pal * 1e6,
            "us_build": t_build * 1e6,
            "recall_at_10": rec, "ivf_speedup_vs_exact": speedup,
            "us_ivf_int8": t_q8 * 1e6, "recall_at_10_int8": rec_q8,
        }
        if N == sizes[-1]:
            # build early-stop delta (ISSUE 9): re-time the default
            # (tol) build warm — the loop's t_build paid the first-shape
            # jit — then the old fixed-iteration build, so the ratio
            # compares algorithm, not compile cache state; recall must be
            # unchanged
            t0 = time.perf_counter()
            build_ivf_index(np.asarray(bank), nlist=nlist, iters=6)
            t_build_warm = time.perf_counter() - t0
            t0 = time.perf_counter()
            fidx = build_ivf_index(np.asarray(bank), nlist=nlist, iters=6,
                                   tol=0)
            t_build_fixed = time.perf_counter() - t0
            _, i_fx10 = jax.jit(
                lambda t, c, pv, pi, q: ivf_search_jnp(t, c, pv, pi, q, 10,
                                                       nprobe))(
                bank, fidx.centroids, fidx.packed_vecs, fidx.packed_ids, q)
            rec_fixed = _recall(i_fx10, np.asarray(i_ex10))
            rows.append({"name": f"nn_search/ivf_build_fixed/N={N}",
                         "us_per_call": t_build_fixed * 1e6,
                         "derived":
                             f"earlystop_x{t_build_fixed / t_build_warm:.2f},"
                             f"recall_delta={rec - rec_fixed:+.3f}"})
            raw["sizes"][str(N)]["us_build_warm"] = t_build_warm * 1e6
            raw["sizes"][str(N)]["us_build_fixed"] = t_build_fixed * 1e6
            raw["sizes"][str(N)]["recall_at_10_fixed"] = rec_fixed

    # the sharded-IVF block below measures the loop's LAST bank/queries/
    # exact baseline; bind them explicitly so later edits to the loop or
    # the sharding-claim block cannot silently change what it measures
    last_bank, last_q, last_i_ex10 = bank, q, i_ex10

    # sharding claim: latency of one shard of N/16 + merge of 16*k candidates
    N = sizes[-1]
    mono_bank = jnp.asarray(clustered_bank(N, D, 64, noise=0.2, seed=1))
    mq = jax.random.normal(jax.random.key(0), (B, D))
    shard = mono_bank[:N // 16]
    t_shard = _t(jax.jit(lambda q, b: ref.nn_search_ref(q, b, k)), mq, shard)
    cand_s = jax.random.normal(jax.random.key(2), (B, 16 * k))
    t_merge = _t(jax.jit(lambda s: jax.lax.top_k(s, k)), cand_s)
    t_mono = _t(jax.jit(lambda q, b: ref.nn_search_ref(q, b, k)), mq,
                mono_bank)
    rows.append({"name": f"nn_search/sharded16/N={N}",
                 "us_per_call": (t_shard + t_merge) * 1e6,
                 "derived": f"vs_monolithic_x{(t_shard+t_merge)/t_mono:.2f}"})

    # -- sharded IVF vs sharded exact (ISSUE 3 acceptance) -----------------
    # per-shard sub-indexes over the loop's last clustered bank, reusing
    # its queries and exact k=10 baseline — same perturbed-bank-row
    # neighbor-discovery workload. knobs: ~16 rows per bucket per shard
    # (the host sim pays gather cost per shortlisted row, so small
    # balanced buckets win) and nprobe=1 PER SHARD — the hierarchical
    # merge still unions S probed buckets globally. At full size
    # (N=65536) this holds recall@10 = 1.0 with >= 3x over sharded-exact;
    # the tiny --quick sizes cluster too coarsely for either bound and
    # only smoke-test the path (the derived column reports the truth)
    S = 16
    n_local = N // S
    nlist_s = max(8, n_local // 16)
    nprobe_s = 1
    t0 = time.perf_counter()
    sidx = build_sharded_ivf_index(np.asarray(last_bank), S, nlist=nlist_s,
                                   iters=6)
    t_sbuild = time.perf_counter() - t0
    sivf_args = (last_bank, sidx.centroids, sidx.packed_vecs,
                 sidx.packed_ids)
    sivf_fn = jax.jit(lambda t, c, pv, pi, q: ivf_search_sharded_jnp(
        t, c, pv, pi, q, k, nprobe_s, n_shards=S))
    sexact_fn = lambda q, b: _sharded_exact(q, b, k, S)  # jitted decorator
    t_sex, t_siv = _t_pair(sexact_fn, (last_q, last_bank),
                           sivf_fn, (*sivf_args, last_q))
    _, i_si10 = jax.jit(lambda t, c, pv, pi, q: ivf_search_sharded_jnp(
        t, c, pv, pi, q, 10, nprobe_s, n_shards=S))(*sivf_args, last_q)
    s_rec = _recall(i_si10, np.asarray(last_i_ex10))
    s_speedup = t_sex / t_siv
    rows.append({"name": f"nn_search/sharded_exact{S}/N={N}",
                 "us_per_call": t_sex * 1e6,
                 "derived": f"qps={B/t_sex:.0f}"})
    rows.append({"name": f"nn_search/sharded_ivf{S}/N={N}",
                 "us_per_call": t_siv * 1e6,
                 "derived": f"recall@10={s_rec:.3f},"
                            f"vs_sharded_exact_x{s_speedup:.1f},"
                            f"nprobe={nprobe_s}"})
    rows.append({"name": f"nn_search/sharded_ivf_build{S}/N={N}",
                 "us_per_call": t_sbuild * 1e6,
                 "derived": f"nlist/shard={sidx.nlist},"
                            f"cap={sidx.bucket_cap}"})
    raw["sharded"] = {
        "N": N, "n_shards": S, "nlist_per_shard": sidx.nlist,
        "nprobe": nprobe_s, "bucket_cap": sidx.bucket_cap,
        "us_sharded_exact": t_sex * 1e6, "us_sharded_ivf": t_siv * 1e6,
        "us_build": t_sbuild * 1e6, "recall_at_10": s_rec,
        "ivf_speedup_vs_sharded_exact": s_speedup,
    }

    # -- skew-proof stage 2 (ISSUE 9): padded vs per-bucket-chunk plan -----
    # a 70%-in-one-cluster bank makes bucket occupancy wildly unequal, so
    # the common bucket_cap pads most buckets heavily; the chunk plan
    # iterates only occupied chunks. Work = summed valid chunks per query
    # batch; the results must stay bit-identical either way.
    Nsk = 2048           # small on purpose: interpret-mode logic timing
    srng = np.random.default_rng(31)
    fat = (0.05 * srng.normal(size=(int(Nsk * 0.7), D)) + 3.0)
    rest = srng.normal(size=(Nsk - fat.shape[0], D))
    skew_bank = jnp.asarray(np.concatenate([fat, rest])
                            .astype(np.float32)[srng.permutation(Nsk)])
    skidx = build_ivf_index(np.asarray(skew_bank), nlist=16, iters=6)
    occ = np.asarray(skidx.bucket_occ)
    skq = jnp.asarray(srng.normal(size=(B, D)).astype(np.float32))
    lb = _chunk_rows(skidx.bucket_cap, 256)
    sk_probes = ivf_probes(skq, skidx.centroids, 4)
    _, nv_full = ivf_chunk_plan(sk_probes, None, skidx.bucket_cap // lb, lb)
    _, nv_occ = ivf_chunk_plan(sk_probes, skidx.bucket_occ,
                               skidx.bucket_cap // lb, lb)
    work_x = float(nv_full.sum()) / max(1.0, float(nv_occ.sum()))
    pad_fn = jax.jit(lambda t, c, pv, pi, q: ivf_search_pallas(
        t, c, pv, pi, q, k, 4, interpret=True))
    chk_fn = jax.jit(lambda t, c, pv, pi, o, q: ivf_search_pallas(
        t, c, pv, pi, q, k, 4, bucket_occ=o, interpret=True))
    sk_args = (skew_bank, skidx.centroids, skidx.packed_vecs,
               skidx.packed_ids)
    # reps=2: interpret mode is slow and its absolute time is logic
    # timing anyway — the work_x chunk ratio is the claim here
    t_pad = _t(pad_fn, *sk_args, skq, reps=2)
    t_chk = _t(chk_fn, *sk_args, skidx.bucket_occ, skq, reps=2)
    s_pad, i_pad = pad_fn(*sk_args, skq)
    s_chk, i_chk = chk_fn(*sk_args, skidx.bucket_occ, skq)
    identical = bool((np.asarray(i_pad) == np.asarray(i_chk)).all()
                     and (np.asarray(s_pad) == np.asarray(s_chk)).all())
    rows.append({"name": f"nn_search/ivf_skew_padded/N={Nsk}",
                 "us_per_call": t_pad * 1e6,
                 "derived": f"chunks={int(nv_full.sum())},"
                            f"occ_min={int(occ.min())},"
                            f"occ_max={int(occ.max())}"})
    rows.append({"name": f"nn_search/ivf_skew_chunked/N={Nsk}",
                 "us_per_call": t_chk * 1e6,
                 "derived": f"chunks={int(nv_occ.sum())},"
                            f"work_x{work_x:.2f},identical={identical}"})
    raw["skew"] = {
        "N": Nsk, "nlist": skidx.nlist, "bucket_cap": skidx.bucket_cap,
        "occ_min": int(occ.min()), "occ_max": int(occ.max()),
        "chunks_padded": int(nv_full.sum()),
        "chunks_occupied": int(nv_occ.sum()),
        "work_ratio": work_x, "identical": identical,
        "us_padded": t_pad * 1e6, "us_chunked": t_chk * 1e6,
    }

    # -- autotuned operating point (ISSUE 9) -------------------------------
    from repro.core.ann_autotune import sweep_ann
    at_n = 4096 if quick else 16384
    at_bank = clustered_bank(at_n, D, 32, noise=0.2, seed=21)
    at_q = clustered_bank(64, D, 32, noise=0.2, seed=22)
    tune = sweep_ann(at_bank, at_q, k=10,
                     nlists=(16, 32) if quick else (32, 64, 128),
                     nprobes=(2, 4) if quick else (4, 8, 16),
                     iters=6)
    win = tune["best"]["fp32"]
    rows.append({"name": f"nn_search/autotuned/N={at_n}",
                 "us_per_call": win["search_s"] * 1e6,
                 "derived": f"nlist={win['nlist']},nprobe={win['nprobe']},"
                            f"recall@10={win['recall']:.3f},"
                            f"meets_floor={win['meets_floor']}"})
    raw["autotuned"] = tune["best"]

    with open("BENCH_nn_search.json", "w") as f:
        json.dump({"rows": rows, **raw}, f, indent=2)
    return rows
