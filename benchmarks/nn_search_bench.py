"""Paper §3.2: nearest-neighbor lookup over the bank.

Three claims measured, on clustered (mixture-of-Gaussians) banks — the
distribution the IVF index is built for:

- exact paths: the jnp reference AND the blocked Pallas kernel (interpret
  mode — logic timing on CPU, not TPU perf);
- IVF vs exact (ISSUE 2 acceptance): the two-stage clustered search must
  beat the exact path >= 5x at N=65536 (B=16, k=8) while keeping
  recall@10 >= 0.95 — measured and reported in the ``derived`` column;
- constant-latency-via-sharding: per-shard work is N/shards, hierarchical
  merge is O(k * shards).

Emits ``BENCH_nn_search.json`` (cwd) with every row plus the raw
speedup/recall numbers so CI and later sessions can diff them.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ann_index import build_ivf_index, clustered_bank
from repro.kernels import ops, ref
from repro.kernels.nn_search_ivf import ivf_search_jnp


def _t(fn, *args, reps=5):
    """Min-of-reps per-call latency (min is the noise-robust estimator on a
    shared/loaded host; means here swing 2x run-to-run)."""
    out = fn(*args)
    jax.block_until_ready(out[0])
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out[0])
        best = min(best, time.perf_counter() - t0)
    return best


def _t_pair(fn_a, args_a, fn_b, args_b, reps=12):
    """Interleaved min-of-reps for A-vs-B claims: a load spike on a shared
    host then penalizes both sides instead of whichever happened to be on
    the clock (back-to-back blocks here have produced 1.2x-12x swings in
    the same speedup)."""
    for fn, args in ((fn_a, args_a), (fn_b, args_b)):
        jax.block_until_ready(fn(*args)[0])
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args_a)[0])
        t1 = time.perf_counter()
        jax.block_until_ready(fn_b(*args_b)[0])
        best_a = min(best_a, t1 - t0)
        best_b = min(best_b, time.perf_counter() - t1)
    return best_a, best_b


def _recall(ids_approx, ids_exact):
    B, k = ids_exact.shape
    hits = sum(len(set(np.asarray(ids_approx)[b]) &
                   set(np.asarray(ids_exact)[b])) for b in range(B))
    return hits / (B * k)


def run(quick: bool = False) -> List[Dict]:
    D, B, k = 64, 16, 8
    sizes = [4096, 16384] if quick else [4096, 16384, 65536]
    rows: List[Dict] = []
    raw = {"config": {"D": D, "B": B, "k": k, "recall_k": 10},
           "sizes": {}}
    for N in sizes:
        nlist = max(16, int(N ** 0.5))          # ~sqrt(N) partitions
        # nprobe tuned per size: clustered banks keep recall@10 = 1.0 down
        # to nprobe=2 (a query's neighbors live in its own cluster); the
        # measured recall in the derived column keeps this honest
        nprobe = 2 if N >= 65536 else 4
        bank = jnp.asarray(clustered_bank(N, D, nlist, noise=0.2, seed=1))
        # queries: perturbed bank rows (neighbor-discovery workload)
        qi = jax.random.randint(jax.random.key(2), (B,), 0, N)
        q = bank[qi] + 0.1 * jax.random.normal(jax.random.key(3), (B, D))

        # -- IVF index (built off the serving path) ------------------------
        t0 = time.perf_counter()
        idx = build_ivf_index(np.asarray(bank), nlist=nlist, iters=6)
        t_build = time.perf_counter() - t0

        # -- exact vs IVF, interleaved (the headline claim) ----------------
        exact_fn = jax.jit(lambda q, b: ref.nn_search_ref(q, b, k))
        ivf_args = (bank, idx.centroids, idx.packed_vecs, idx.packed_ids)
        jnp_fn = jax.jit(
            lambda t, c, pv, pi, q: ivf_search_jnp(t, c, pv, pi, q, k,
                                                   nprobe))
        t_ref, t_ivf = _t_pair(exact_fn, (q, bank), jnp_fn, (*ivf_args, q))
        rows.append({"name": f"nn_search/ref/N={N}",
                     "us_per_call": t_ref * 1e6,
                     "derived": f"qps={B/t_ref:.0f}"})
        t_pal = _t(lambda q, b: ops.nn_search_topk(q, b, k), q, bank)
        rows.append({"name": f"nn_search/pallas/N={N}",
                     "us_per_call": t_pal * 1e6,
                     "derived": f"interpret_vs_ref_x{t_pal/t_ref:.1f}"})
        rows.append({"name": f"nn_search/ivf_build/N={N}",
                     "us_per_call": t_build * 1e6,
                     "derived": f"nlist={idx.nlist},cap={idx.bucket_cap}"})
        # recall@10 against brute force (k=10 searches on both sides)
        _, i_ex10 = jax.jit(lambda q, b: ref.nn_search_ref(q, b, 10))(q, bank)
        _, i_iv10 = jax.jit(
            lambda t, c, pv, pi, q: ivf_search_jnp(t, c, pv, pi, q, 10,
                                                   nprobe))(*ivf_args, q)
        rec = _recall(i_iv10, np.asarray(i_ex10))
        speedup = t_ref / t_ivf
        rows.append({"name": f"nn_search/ivf/N={N}",
                     "us_per_call": t_ivf * 1e6,
                     "derived": f"recall@10={rec:.3f},"
                                f"vs_exact_x{speedup:.1f},nprobe={nprobe}"})
        t_ivf_pal = _t(lambda t, c, pv, pi, q: ops.nn_search_ivf(
            t, c, pv, pi, q, k, nprobe), *ivf_args, q)
        rows.append({"name": f"nn_search/ivf_pallas/N={N}",
                     "us_per_call": t_ivf_pal * 1e6,
                     "derived": f"interpret_vs_pallas_exact_"
                                f"x{t_ivf_pal/t_pal:.2f}"})
        raw["sizes"][str(N)] = {
            "nlist": idx.nlist, "nprobe": nprobe,
            "bucket_cap": idx.bucket_cap,
            "us_exact_ref": t_ref * 1e6, "us_exact_pallas": t_pal * 1e6,
            "us_ivf_ref": t_ivf * 1e6, "us_ivf_pallas": t_ivf_pal * 1e6,
            "us_build": t_build * 1e6,
            "recall_at_10": rec, "ivf_speedup_vs_exact": speedup,
        }

    # sharding claim: latency of one shard of N/16 + merge of 16*k candidates
    N = sizes[-1]
    bank = jnp.asarray(clustered_bank(N, D, 64, noise=0.2, seed=1))
    q = jax.random.normal(jax.random.key(0), (B, D))
    shard = bank[:N // 16]
    t_shard = _t(jax.jit(lambda q, b: ref.nn_search_ref(q, b, k)), q, shard)
    cand_s = jax.random.normal(jax.random.key(2), (B, 16 * k))
    t_merge = _t(jax.jit(lambda s: jax.lax.top_k(s, k)), cand_s)
    t_mono = _t(jax.jit(lambda q, b: ref.nn_search_ref(q, b, k)), q, bank)
    rows.append({"name": f"nn_search/sharded16/N={N}",
                 "us_per_call": (t_shard + t_merge) * 1e6,
                 "derived": f"vs_monolithic_x{(t_shard+t_merge)/t_mono:.2f}"})

    with open("BENCH_nn_search.json", "w") as f:
        json.dump({"rows": rows, **raw}, f, indent=2)
    return rows
