"""Turn dry-run JSONL records into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_baseline.jsonl
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def load(path):
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def table(recs, multi_pod: bool) -> str:
    rows = []
    head = ("| arch | shape | mem/dev GiB | fits 16G | compute s | memory s |"
            " collective s | bottleneck | useful (6ND/HLO) | top collective |")
    sep = "|" + "---|" * 10
    rows.append(head)
    rows.append(sep)
    for r in recs:
        if r.get("error"):
            if bool(r.get("multi_pod")) == multi_pod:
                rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | |"
                            f" {r['error'][:40]} | | |")
            continue
        if bool(r.get("multi_pod")) != multi_pod:
            continue
        rl = r["roofline"]
        m = r["memory"]
        cb = rl.get("collective_bytes", {})
        top = max(cb, key=cb.get) if cb else "-"
        tops = f"{top} {cb.get(top,0)/2**30:.1f}GiB" if cb else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {m['peak_per_device_gib']} | "
            f"{'Y' if m['fits_16gib'] else 'N'} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"**{rl['bottleneck']}** | {rl['useful_ratio']:.2f} | {tops} |")
    return "\n".join(rows)


def main():
    recs = load(sys.argv[1] if len(sys.argv) > 1
                else "results/dryrun_baseline.jsonl")
    ok = [r for r in recs if not r.get("error")]
    err = [r for r in recs if r.get("error")]
    print(f"<!-- {len(ok)} ok, {len(err)} failed -->\n")
    print("### Single-pod (16x16 = 256 chips)\n")
    print(table(recs, False))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(table(recs, True))
    if err:
        print("\n### Failures\n")
        for r in err:
            print(f"- {r['arch']} x {r['shape']} mp={r.get('multi_pod')}: "
                  f"{r['error'][:200]}")


if __name__ == "__main__":
    main()
