"""Paper §4.3: multimodal two-tower contrastive learning. (a) looking up
historical embeddings from the KB instead of encoding both towers every
step cuts trainer compute; (b) the KB lets the negative pool scale far
beyond the batch "for free" — more negatives => better retrieval."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import kb_create, kb_lookup, kb_update
from repro.data import PairedCorpus
from repro.models import build_model
from repro.models.losses import contrastive_loss, masked_mean_pool
from repro.optim import AdamW, constant_lr
from repro.sharding.partition import DistContext

DIST = DistContext()


def _towers(cfg):
    ma = build_model(cfg)
    mb = build_model(cfg)
    ka, kb_ = jax.random.split(jax.random.key(0))
    return ma, mb, {"a": ma.init(ka), "b": mb.init(kb_)}


def _embed(model, params, toks):
    h, _, _, _ = model.hidden(params, toks, {}, DIST)
    return masked_mean_pool(h, jnp.ones(toks.shape, jnp.float32))


def run(quick: bool = False) -> List[Dict]:
    cfg = get_config("internvl2-2b").reduced().replace(
        num_layers=2, frontend="none")
    # NOTE scale matters for the quality side of this claim: at 512 pairs /
    # 40 steps recall is flat-to-worse with pool size; at 1024 / 60 it
    # improves monotonically (see EXPERIMENTS.md §two-tower).
    corpus = PairedCorpus(num_pairs=1024, vocab_size=cfg.vocab_size,
                          num_concepts=32, seed=0)
    ma, mb, params = _towers(cfg)
    opt = AdamW(lr=constant_lr(2e-3), weight_decay=0.0)
    steps = 10 if quick else 60
    B = 16
    rows = []
    for n_neg in ([0, 128] if quick else [0, 64, 256]):
        p = jax.tree.map(lambda x: x, params)
        st = opt.init(p)
        kb = kb_create(corpus.num_pairs, cfg.d_model)

        @jax.jit
        def step(p, st, kb, ta, tb, neg_ids):
            negs, kb = kb_lookup(kb, neg_ids, apply_pending=False)

            def loss_fn(p):
                ea = _embed(ma, p["a"], ta)
                eb = _embed(mb, p["b"], tb)
                extra = negs if n_neg else None
                return contrastive_loss(ea, eb, extra_negatives=extra), (ea,
                                                                         eb)

            (l, (ea, eb)), g = jax.value_and_grad(loss_fn,
                                                  has_aux=True)(p)
            p, st, _ = opt.update(g, st, p)
            return p, st, kb, l, eb

        rng = np.random.default_rng(0)
        t_acc = []
        for s in range(steps):
            b = corpus.batch(rng, B)
            neg_ids = jnp.asarray(rng.integers(0, corpus.num_pairs,
                                               (max(n_neg, 1),)))
            t0 = time.perf_counter()
            p, st, kb, l, eb = step(p, st, kb, jnp.asarray(b["tokens_a"]),
                                    jnp.asarray(b["tokens_b"]), neg_ids)
            jax.block_until_ready(eb)
            if s > 0:
                t_acc.append(time.perf_counter() - t0)
            # maker role: push tower-b embeddings for future negatives
            kb = kb_update(kb, jnp.asarray(b["ids"]), eb)
        # retrieval eval: recall@1 of tower-a query over 128 tower-b items
        ev = corpus.batch(np.random.default_rng(99), 128)
        ea = _embed(ma, p["a"], jnp.asarray(ev["tokens_a"]))
        eb = _embed(mb, p["b"], jnp.asarray(ev["tokens_b"]))
        sim = np.asarray(ea @ eb.T)
        r1 = float((sim.argmax(1) == np.arange(len(sim))).mean())
        rows.append({"name": f"two_tower/negatives={n_neg}",
                     "us_per_call": float(np.mean(t_acc)) * 1e6,
                     "derived": f"recall@1={r1:.3f} loss={float(l):.3f}"})
    return rows
