"""Paper §1/§4.1 headline claim: with CARLS, trainer step cost is ~flat in
the number of graph-regularization neighbors K (they are *looked up*), while
the conventional baseline that encodes neighbors in-trainer grows linearly.

Two measurements per K: wall-clock per step (CPU, small model) and compiled
per-step FLOPs (platform-independent; the shape of the curve is the claim).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import cost_analysis
from repro.configs import get_config
from repro.core import kb_create, make_carls_train_step, \
    make_inline_baseline_step
from repro.data import SyntheticGraphCorpus
from repro.models import build_model
from repro.optim import AdamW, constant_lr
from repro.sharding.partition import DistContext

DIST = DistContext()


def _time_steps(fn, args, reps=5):
    out = fn(*args)                      # compile
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return (time.perf_counter() - t0) / reps


def run(quick: bool = False) -> List[Dict]:
    ks = [1, 4, 8] if quick else [1, 2, 4, 8, 16]
    cfg0 = get_config("yi-6b").reduced().replace(num_layers=2)
    opt = AdamW(lr=constant_lr(1e-3))
    corpus = SyntheticGraphCorpus(num_nodes=512, vocab_size=cfg0.vocab_size,
                                  seq_len=33, neighbors_per_node=max(ks))
    rng = np.random.default_rng(0)
    B = 4
    b = corpus.batch(rng, B)
    rows = []
    for K in ks:
        cfg = cfg0.replace(carls=cfg0.carls.__class__(
            **{**cfg0.carls.__dict__, "num_neighbors": K,
               "kb_entries": 512}))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        kb = kb_create(512, cfg.d_model)
        jb = {k: jnp.asarray(v) for k, v in b.items()}
        jb["neighbor_ids"] = jnp.asarray(b["neighbor_ids"][:, :K])
        jb["neighbor_weights"] = jnp.asarray(b["neighbor_weights"][:, :K])

        step_c = jax.jit(make_carls_train_step(model, opt, DIST))
        t_carls = _time_steps(step_c, (params, opt.init(params), kb, jb))
        f_carls = cost_analysis(step_c.lower(params, opt.init(params),
                                             kb, jb).compile())["flops"]

        jb2 = dict(jb)
        jb2["neighbor_tokens"] = jnp.asarray(
            corpus.neighbor_tokens(b["neighbor_ids"][:, :K]))
        step_b = jax.jit(make_inline_baseline_step(model, opt, DIST,
                                                   num_neighbors=K))
        t_base = _time_steps(step_b, (params, opt.init(params), jb2))
        f_base = cost_analysis(step_b.lower(params, opt.init(params),
                                            jb2).compile())["flops"]
        rows.append({"name": f"neighbor_scaling/K={K}/carls",
                     "us_per_call": t_carls * 1e6,
                     "derived": f"flops={f_carls:.3g}"})
        rows.append({"name": f"neighbor_scaling/K={K}/inline_baseline",
                     "us_per_call": t_base * 1e6,
                     "derived": f"flops={f_base:.3g}"})
    return rows
