"""Paper §1: "the impacts of [data freshness] are controllable and not
significant". Sweep the checkpoint publish period (the asynchrony knob —
larger period = staler maker embeddings) and record final training loss +
measured mean staleness."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs import get_config
from repro.core import run_async_training
from repro.data import SyntheticGraphCorpus
from repro.models import build_model


def run(quick: bool = False) -> List[Dict]:
    periods = [1, 20] if quick else [1, 5, 20, 50]
    steps = 24 if quick else 60
    cfg = get_config("yi-6b").reduced().replace(num_layers=2)
    model = build_model(cfg)
    rows = []
    for p in periods:
        corpus = SyntheticGraphCorpus(num_nodes=256,
                                      vocab_size=cfg.vocab_size, seq_len=17,
                                      neighbors_per_node=4, seed=0)
        res = run_async_training(model, corpus, steps=steps, batch_size=8,
                                 num_makers=1, maker_batch=32,
                                 ckpt_period=p, lr=3e-3, seed=0)
        rows.append({
            "name": f"staleness/ckpt_period={p}",
            "us_per_call": float(np.mean(res.step_times[2:])) * 1e6,
            "derived": (f"final_loss={np.mean(res.losses[-5:]):.4f} "
                        f"mean_staleness={res.mean_staleness:.1f} "
                        f"refreshes={res.maker_refreshes}")})
    return rows
